//! `mozart` CLI — the L3 coordinator entrypoint.
//!
//! [`HELP`] below is the single source of truth for the subcommand list and
//! every flag; a unit test asserts each subcommand in [`SUBCOMMANDS`]
//! appears there, so the dispatch table and the documentation cannot drift.

use anyhow::{bail, Context, Result};
use mozart::config::{DramKind, ExperimentConfig, Method, ModelConfig, ModelId};
use mozart::coordinator::explore::{self, ExploreConfig};
use mozart::coordinator::sweep::{
    self, cell_config, run_cells_seq, run_cells_with, Cell, SweepOptions,
};
use mozart::report::{self, ReportOpts};
use mozart::testkit::bench;
use mozart::util::cli::Args;
use mozart::util::json::Json;

/// Every dispatchable subcommand, in help order.
const SUBCOMMANDS: [&str; 8] = [
    "report", "simulate", "layout", "bench", "explore", "train", "platform", "help",
];

/// The full usage text (`mozart help`). Documents every subcommand and every
/// flag in one place; keep in sync with the `match` in [`main`] (enforced by
/// the `help_lists_every_subcommand` test).
const HELP: &str = "\
mozart — MoE training on 3.5D wafer-scale chiplets (NeurIPS 2025 reproduction)

USAGE: mozart <command> [options]

COMMANDS:
  report <what>   regenerate a paper table/figure: table1 table2 table3
                  table4 fig1 fig3 fig6b fig6c fig7 fig8 fig9 fig10_13
                  fig14_16 q1 q2 q3 all   [--iters N] [--seed N]
  simulate        one experiment cell: --model qwen3|olmoe|deepseek|tiny
                  --method baseline|a|b|c [--seq N] [--dram hbm2|ssd]
                  [--iters N] [--seed N] [--config file]
  layout          expert clustering + allocation: --model ... [--seed N]
  bench           time the sweep + explore grids (sequential vs parallel
                  executor) and write BENCH_sweep.json:
                  [--grid table3|appendix|explore|all] [--iters N] [--seed N]
                  [--threads N] [--reps N] [--out BENCH_sweep.json]
  explore         design-space exploration: expand a hardware axis grid, run
                  every (variant x model x method) cell, report the Pareto
                  frontier over (latency, energy, area) vs the paper's
                  Table 2 point, and write an EXPLORE_*.json artifact:
                  [--axes tiles,nop_bw,dram | tiles=36:64:100,...]
                  [--budget N] [--model qwen3|olmoe|deepseek|tiny|all]
                  [--method baseline|a|b|c|all] [--seq N] [--dram hbm2|ssd]
                  [--iters N] [--seed N] [--threads N]
                  [--out EXPLORE_design_space.json]
  train           real end-to-end training of the tiny MoE via PJRT:
                  [--steps N] [--artifacts artifacts/] [--log-every N]
                  [--seed N]
  platform        print the PJRT platform (runtime smoke check)
  help            print this message";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "report" => cmd_report(&args),
        "simulate" => cmd_simulate(&args),
        "layout" => cmd_layout(&args),
        "bench" => cmd_bench(&args),
        "explore" => cmd_explore(&args),
        "train" => cmd_train(&args),
        "platform" => cmd_platform(),
        "help" | "--help" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `mozart help`)"),
    }
}

fn report_opts(args: &Args) -> Result<ReportOpts> {
    Ok(ReportOpts {
        iters: args.get_parse("iters", 4)?,
        seed: args.get_parse("seed", 7)?,
    })
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = report_opts(args)?;
    let emit = |name: &str| -> Result<()> {
        let out = match name {
            "table1" => report::table1(),
            "table2" => report::table2(),
            "table3" => report::table3(opts).0,
            "table4" => report::table4(opts),
            "fig1" => report::fig1(),
            "fig3" => report::fig3(opts),
            "fig6b" => report::fig6b(opts),
            "fig6c" => report::fig6c(opts),
            "fig7" => report::appendix_fig(128, opts),
            "fig8" => report::appendix_fig(256, opts),
            "fig9" => report::appendix_fig(512, opts),
            "fig10_13" => report::fig10_13(),
            "fig14_16" => report::fig14_16(opts),
            "q1" => report::q1(opts),
            "q2" => report::q2(opts),
            "q3" => report::q3(opts),
            other => bail!("unknown report `{other}`"),
        };
        println!("{out}");
        Ok(())
    };
    if what == "all" {
        for name in [
            "table1", "table2", "table3", "table4", "fig1", "fig3", "fig6b", "fig6c",
            "fig7", "fig8", "fig9", "fig10_13", "fig14_16", "q1", "q2", "q3",
        ] {
            emit(name)?;
        }
        Ok(())
    } else {
        emit(what)
    }
}

/// Shared `--dram` option parsing (one spelling table for every subcommand).
fn parse_dram(args: &Args) -> Result<DramKind> {
    DramKind::from_name(args.get_or("dram", "hbm2"))
        .context("unknown --dram (hbm2|ssd)")
}

fn parse_cell(args: &Args) -> Result<Cell> {
    let model = ModelId::from_name(args.get_or("model", "qwen3"))
        .context("unknown --model (qwen3|olmoe|deepseek|tiny)")?;
    let method = Method::from_name(args.get_or("method", "c"))
        .context("unknown --method (baseline|a|b|c)")?;
    let dram = parse_dram(args)?;
    Ok(Cell {
        model,
        method,
        seq_len: args.get_parse("seq", 256)?,
        dram,
    })
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cell = parse_cell(args)?;
    let iters = args.get_parse("iters", 4)?;
    let seed = args.get_parse("seed", 7)?;
    let mut cfg: ExperimentConfig = cell_config(cell, iters, seed);
    if let Some(path) = args.get("config") {
        let kv = mozart::config::parse::KvConfig::load(path)?;
        kv.apply_knobs(&mut cfg.hw.knobs)?;
        cfg.seq_len = kv.get_usize("workload.seq_len", cfg.seq_len)?;
        cfg.batch_size = kv.get_usize("workload.batch_size", cfg.batch_size)?;
        cfg.micro_batch = kv.get_usize("workload.micro_batch", cfg.micro_batch)?;
    }
    let r = mozart::coordinator::run_experiment(&cfg);
    println!(
        "model={} method={} seq={} dram={} iters={}",
        cell.model.name(),
        cell.method.name(),
        cell.seq_len,
        cell.dram.name(),
        iters
    );
    println!(
        "latency: {:.4} s/step (std {:.4})   C_T: {:.2}   energy: {:.1} J/step",
        r.latency,
        r.latency_std,
        r.c_t,
        r.energy.total_j()
    );
    println!(
        "group imbalance: {:.3}   MoE utilization: {:.3}",
        r.group_imbalance, r.moe_utilization
    );
    println!("\nbusy time per component (s/step):");
    let mut rows = r.tag_busy.to_vec();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (tag, v) in rows.iter().filter(|(_, v)| *v > 0.0) {
        println!("  {:<18} {:.4}", tag.name(), v);
    }
    println!("\ncritical path (s/step):");
    let mut rows = r.critical.to_vec();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (tag, v) in rows.iter().filter(|(_, v)| *v > 0.0) {
        println!("  {:<18} {:.4}", tag.name(), v);
    }
    Ok(())
}

/// `mozart explore`: expand the hardware axis grid, evaluate every
/// (variant x model x method) cell over the work-stealing pool, print the
/// Pareto report, and write the `EXPLORE_*.json` artifact.
fn cmd_explore(args: &Args) -> Result<()> {
    let axes = match explore::parse_axes(args.get_or("axes", "tiles,nop_bw,dram")) {
        Ok(a) => a,
        Err(e) => bail!("bad --axes: {e}"),
    };
    let models: Vec<ModelId> = match args.get_or("model", "qwen3").to_ascii_lowercase().as_str()
    {
        "all" => ModelId::PAPER_MODELS.to_vec(),
        s => vec![ModelId::from_name(s)
            .context("unknown --model (qwen3|olmoe|deepseek|tiny|all)")?],
    };
    let methods: Vec<Method> = match args.get_or("method", "c").to_ascii_lowercase().as_str() {
        "all" => Method::ALL.to_vec(),
        s => vec![Method::from_name(s).context("unknown --method (baseline|a|b|c|all)")?],
    };
    let dram = parse_dram(args)?;
    let cfg = ExploreConfig {
        axes,
        budget: args.get_parse("budget", 64)?,
        models,
        methods,
        seq_len: args.get_parse("seq", 256)?,
        dram,
        iters: args.get_parse("iters", 2)?,
        seed: args.get_parse("seed", 7)?,
        threads: args.get_parse("threads", 0)?,
    };
    let outcome = explore::explore(&cfg);
    println!("{}", outcome.render_markdown());
    let out_path = args.get_or("out", "EXPLORE_design_space.json");
    std::fs::write(out_path, outcome.to_json().render_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// `mozart bench`: time the sweep and explore grids through the sequential
/// reference path and the parallel executor, verify the results are
/// bit-identical, and write a machine-readable `BENCH_sweep.json` so the
/// performance trajectory is tracked from PR to PR.
fn cmd_bench(args: &Args) -> Result<()> {
    let grid = args.get_or("grid", "all").to_ascii_lowercase();
    let iters: usize = args.get_parse("iters", 2)?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let reps: usize = args.get_parse("reps", 1)?.max(1);
    let threads: usize = args.get_parse("threads", 0)?;
    let out_path = args.get_or("out", "BENCH_sweep.json").to_string();
    let opts = SweepOptions { threads };

    let mut grids: Vec<(&str, Vec<Cell>)> = Vec::new();
    let mut bench_explore = false;
    match grid.as_str() {
        "table3" => grids.push(("table3", sweep::table3_cells())),
        "appendix" => grids.push(("appendix_seq128", sweep::appendix_cells(128))),
        "explore" => bench_explore = true,
        "all" => {
            grids.push(("table3", sweep::table3_cells()));
            grids.push(("appendix_seq128", sweep::appendix_cells(128)));
            bench_explore = true;
        }
        other => bail!("unknown --grid {other} (table3|appendix|explore|all)"),
    }

    let mut grid_reports: Vec<Json> = Vec::new();
    println!("sweep bench: iters={iters} seed={seed} reps={reps}\n");

    for (name, cells) in &grids {
        let n = cells.len();
        // worker count actually used for THIS grid (capped at its cell count)
        let n_workers = opts.effective_threads(n);
        // keep the last timed pass's results so the determinism check below
        // does not have to re-run the (slow) sweeps a further time
        let mut seq_results = None;
        let seq = bench(&format!("sweep[{name}]: sequential, {n} cells"), reps, || {
            seq_results = Some(run_cells_seq(cells, iters, seed));
        });
        let mut par_results = None;
        let par = bench(&format!("sweep[{name}]: parallel,   {n} cells"), reps, || {
            par_results = Some(run_cells_with(cells, iters, seed, opts));
        });

        // determinism check: the parallel executor must reproduce the
        // sequential results bit for bit
        let a = seq_results.expect("reps >= 1 guarantees one sequential pass");
        let b = par_results.expect("reps >= 1 guarantees one parallel pass");
        let identical = a.len() == b.len()
            && a.iter().zip(b.iter()).all(|(x, y)| {
                x.result.latency == y.result.latency
                    && x.result.c_t == y.result.c_t
                    && x.result.tag_busy == y.result.tag_busy
            });
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> {name}: {:.2}x speedup, {:.2} cells/s parallel, bit-identical: {identical}\n",
            speedup,
            n as f64 / par.mean_s
        );

        grid_reports.push(Json::obj([
            ("name", Json::str(*name)),
            ("cells", Json::int(n)),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("cells_per_s_sequential", Json::num(n as f64 / seq.mean_s)),
            ("cells_per_s_parallel", Json::num(n as f64 / par.mean_s)),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel sweep diverged from sequential on grid {name}");
        }
    }

    if bench_explore {
        // explore hot path: a small tiles x dram grid on the fastest model
        // (6 variants + the paper anchor = 7 cells)
        let mut ecfg = ExploreConfig::paper_default();
        ecfg.models = vec![ModelId::OlmoE_1B_7B];
        ecfg.axes = explore::parse_axes("tiles=36:64:100,dram")
            .map_err(|e| anyhow::anyhow!("explore bench axes: {e}"))?;
        ecfg.budget = 0;
        ecfg.seq_len = 128;
        ecfg.iters = iters;
        ecfg.seed = seed;

        let mut seq_cfg = ecfg.clone();
        seq_cfg.threads = 1;
        let mut par_cfg = ecfg;
        par_cfg.threads = threads;

        let mut seq_out = None;
        let seq = bench("explore[tiles x dram]: sequential", reps, || {
            seq_out = Some(explore::explore(&seq_cfg));
        });
        let mut par_out = None;
        let par = bench("explore[tiles x dram]: parallel", reps, || {
            par_out = Some(explore::explore(&par_cfg));
        });

        let a = seq_out.expect("reps >= 1 guarantees one sequential pass");
        let b = par_out.expect("reps >= 1 guarantees one parallel pass");
        // actual cell count (anchor-duplicate combos are skipped inside
        // explore(), so don't re-derive it from the grid shape)
        let n = a.points.len();
        let n_workers = SweepOptions { threads }.effective_threads(n);
        let identical = a.points.len() == b.points.len()
            && a.points.iter().zip(b.points.iter()).all(|(x, y)| {
                x.variant == y.variant
                    && x.latency_s == y.latency_s
                    && x.energy_j == y.energy_j
                    && x.area_mm2 == y.area_mm2
            });
        let speedup = seq.mean_s / par.mean_s;
        println!(
            "  -> explore: {:.2}x speedup, {:.2} cells/s parallel, bit-identical: {identical}\n",
            speedup,
            n as f64 / par.mean_s
        );
        grid_reports.push(Json::obj([
            ("name", Json::str("explore_tiles_dram")),
            ("cells", Json::int(n)),
            ("workers", Json::int(n_workers)),
            ("sequential", seq.to_json()),
            ("parallel", par.to_json()),
            ("cells_per_s_sequential", Json::num(n as f64 / seq.mean_s)),
            ("cells_per_s_parallel", Json::num(n as f64 / par.mean_s)),
            ("speedup_parallel_vs_sequential", Json::num(speedup)),
            ("bit_identical", Json::Bool(identical)),
        ]));
        if !identical {
            bail!("parallel explore diverged from sequential");
        }
    }

    let report = Json::obj([
        ("bench", Json::str("sweep")),
        ("iters", Json::int(iters)),
        // string, not number: JSON numbers are f64 and would corrupt u64
        // seeds above 2^53, breaking reproduction from the artifact
        ("seed", Json::str(seed.to_string())),
        ("reps", Json::int(reps)),
        ("threads_requested", Json::int(threads)),
        ("grids", Json::Arr(grid_reports)),
    ]);
    std::fs::write(&out_path, report.render_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_layout(args: &Args) -> Result<()> {
    use mozart::trace::{Priors, TraceGen};
    let model_id = ModelId::from_name(args.get_or("model", "qwen3"))
        .context("unknown --model")?;
    let seed: u64 = args.get_parse("seed", 7)?;
    let model = ModelConfig::preset(model_id);
    let gen = TraceGen::for_model(&model, seed);
    let traces = gen.profile(4096, seed ^ 0x50F1_1E);
    let refs: Vec<&mozart::trace::RoutingTrace> = traces.iter().collect();
    let priors = Priors::from_traces(&refs);
    let layout = mozart::allocation::ExpertLayout::mozart(&priors, 16, 4);
    let contiguous =
        mozart::allocation::ExpertLayout::contiguous(model.n_experts, 16, 4);
    println!("model: {}  experts: {}  top-{}", model_id.name(), model.n_experts, model.top_k);
    println!(
        "intra-cluster collaboration: clustered {:.4} vs contiguous {:.4}",
        layout.clustering.intra_collab(&priors),
        contiguous.clustering.intra_collab(&priors)
    );
    println!(
        "inter-cluster collaboration: clustered {:.4} vs contiguous {:.4}",
        layout.clustering.inter_collab(&priors),
        contiguous.clustering.inter_collab(&priors)
    );
    let wl = layout.clustering.cluster_workloads(&priors);
    let gl = layout.allocation.group_workloads(&wl);
    println!("group workloads after Eq.5 allocation: {gl:?}");
    for (c, members) in layout.clustering.clusters.iter().enumerate() {
        let chiplet = layout.allocation.chiplet_of_cluster()[c];
        println!(
            "cluster {c:>2} -> chiplet {chiplet:>2} (group {}): {:?}",
            chiplet / 4,
            members
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let steps = args.get_parse("steps", 200)?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let log_every = args.get_parse("log-every", 10)?;
    let cfg = mozart::train::TrainConfig {
        artifacts_dir: artifacts.to_string(),
        steps,
        log_every,
        seed: args.get_parse("seed", 7)?,
    };
    let summary = mozart::train::run(&cfg)?;
    println!("{}", summary.render());
    Ok(())
}

fn cmd_platform() -> Result<()> {
    println!("PJRT platform: {}", mozart::runtime::platform()?);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_lists_every_subcommand() {
        for cmd in SUBCOMMANDS {
            assert!(
                HELP.lines().any(|l| l.trim_start().starts_with(cmd)),
                "subcommand `{cmd}` missing from help text"
            );
        }
    }

    #[test]
    fn help_documents_the_explore_flags() {
        for flag in ["--axes", "--budget", "--out", "--model", "--method", "--threads"] {
            assert!(HELP.contains(flag), "flag `{flag}` missing from help text");
        }
    }

    #[test]
    fn help_covers_every_report_name() {
        // the `report <what>` list in HELP must name every report the
        // dispatcher accepts (same list as `report all`)
        for name in [
            "table1", "table2", "table3", "table4", "fig1", "fig3", "fig6b", "fig6c",
            "fig7", "fig8", "fig9", "fig10_13", "fig14_16", "q1", "q2", "q3",
        ] {
            assert!(HELP.contains(name), "report `{name}` missing from help text");
        }
    }
}
