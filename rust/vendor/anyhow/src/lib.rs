//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build image for this repository has no crates.io registry, so the
//! workspace vendors the subset of anyhow's API it actually uses: the
//! [`Error`] type, the [`Result`] alias, the [`Context`] extension trait
//! (on both `Result` and `Option`), and the `anyhow!` / `bail!` / `ensure!`
//! macros. The design mirrors the real crate where it matters:
//!
//! - `Error` deliberately does **not** implement `std::error::Error`, which
//!   is what makes the blanket `impl<E: std::error::Error> From<E> for
//!   Error` coherent (the same trick the real anyhow uses), so `?` converts
//!   any standard error into an `Error`.
//! - `Context` is implemented through a local `ext::StdError` trait with
//!   one blanket impl for standard errors and one concrete impl for
//!   `Error`, so `.context()` / `.with_context()` chain on both.
//!
//! Error messages are flattened eagerly into a single string, with source
//! chains joined by `: ` — sufficient for a CLI/reporting crate; swap the
//! real `anyhow` back in `rust/Cargo.toml` when a registry is available.

use std::fmt::{self, Debug, Display};

/// A flattened error message with its context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, ctx: C) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints errors with `{:?}`;
        // keep that output human-readable.
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow: because `Error` does not implement `std::error::Error`,
// this blanket impl cannot overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// `anyhow::Result<T>`: `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;
    use std::fmt::Display;

    /// Local abstraction over "things an `Error` can absorb with context".
    pub trait StdError {
        fn ext_context<C: Display>(self, ctx: C) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> StdError for E {
        fn ext_context<C: Display>(self, ctx: C) -> Error {
            Error::from_std(&self).context(ctx)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, ctx: C) -> Error {
            self.context(ctx)
        }
    }
}

/// Extension trait adding `.context()` / `.with_context()` to `Result` and
/// `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E>: Sized {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.ext_context(f())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::Error::msg(format!($($arg)+)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: missing thing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing key {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing key k");

        let some: Option<u32> = Some(3);
        assert_eq!(some.context("unused").unwrap(), 3);
    }

    #[test]
    fn context_chains_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("inner failure {}", 7);
        }
        let e = inner().context("outer step").unwrap_err();
        assert_eq!(e.to_string(), "outer step: inner failure 7");
    }

    #[test]
    fn ensure_both_arities() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x >= 0);
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1)
            .unwrap_err()
            .to_string()
            .contains("condition failed"));
        assert_eq!(check(12).unwrap_err().to_string(), "x too big: 12");
    }

    #[test]
    fn anyhow_macro_and_debug() {
        let e = anyhow!("v={}", 2);
        assert_eq!(format!("{e}"), "v=2");
        assert_eq!(format!("{e:?}"), "v=2");
    }
}
