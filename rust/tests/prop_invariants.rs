//! Property-based tests over the core invariants (hand-rolled `forall`
//! harness from `mozart::testkit`; proptest is unavailable offline).

use mozart::allocation::{allocate, Allocation, ExpertLayout};
use mozart::clustering::{cluster_experts, Clustering};
use mozart::comm::A2aStats;
use mozart::metrics::pareto;
use mozart::prop_assert;
use mozart::sim::{Plan, Simulator, Tag, TaskSpec};
use mozart::testkit::{constrained_objective_cloud, forall, objective_cloud};
use mozart::trace::{Priors, RoutingTrace};
use mozart::util::rng::Rng;

/// Random routing trace with valid structure.
fn random_trace(rng: &mut Rng) -> RoutingTrace {
    let n_experts = *[16usize, 32, 64, 128].iter().nth(rng.below(4)).unwrap();
    let top_k = 1 + rng.below(8.min(n_experts));
    let n_tokens = 1 + rng.below(300);
    let mut choices = Vec::with_capacity(n_tokens * top_k);
    let weights: Vec<f64> = (0..n_experts).map(|_| rng.f64() + 0.01).collect();
    for _ in 0..n_tokens {
        choices.extend(
            rng.weighted_distinct(&weights, top_k)
                .into_iter()
                .map(|e| e as u32),
        );
    }
    RoutingTrace {
        n_experts,
        top_k,
        choices,
    }
}

#[test]
fn prop_priors_are_normalized_and_symmetric() {
    forall("priors-normalized", 40, |rng| {
        let tr = random_trace(rng);
        tr.validate().map_err(|e| e.to_string())?;
        let p = Priors::from_trace(&tr);
        let sum: f64 = p.workload.iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-9, "workload sums to {sum}");
        for i in 0..tr.n_experts {
            prop_assert!(p.p(i, i) == 0.0, "diagonal must be empty");
            for j in 0..tr.n_experts {
                let (a, b) = (p.p(i, j), p.p(j, i));
                prop_assert!((a - b).abs() < 1e-12, "asymmetric at ({i},{j})");
                prop_assert!((0.0..=1.0).contains(&a), "P out of [0,1]: {a}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_clustering_partitions() {
    forall("clustering-partitions", 30, |rng| {
        let tr = random_trace(rng);
        let p = Priors::from_trace(&tr);
        // any divisor of n_experts up to 16 clusters
        let divisors: Vec<usize> = (1..=16).filter(|d| tr.n_experts % d == 0).collect();
        let nc = divisors[rng.below(divisors.len())];
        let cl = cluster_experts(&p, nc);
        cl.validate().map_err(|e| e.to_string())?;
        prop_assert!(cl.clusters.len() == nc, "wrong cluster count");
        Ok(())
    });
}

#[test]
fn prop_clustering_never_below_contiguous_intra() {
    // Algorithm 1 maximizes intra-cluster collaboration greedily; on any
    // trace it should do at least as well as the arbitrary contiguous split
    // minus numerical noise.
    forall("clustering-intra", 20, |rng| {
        let tr = random_trace(rng);
        let p = Priors::from_trace(&tr);
        if tr.n_experts % 16 != 0 {
            return Ok(());
        }
        let ours = cluster_experts(&p, 16).intra_collab(&p);
        let cont = Clustering::contiguous(tr.n_experts, 16).intra_collab(&p);
        prop_assert!(
            ours >= cont - 1e-9,
            "clustered intra {ours} < contiguous {cont}"
        );
        Ok(())
    });
}

#[test]
fn prop_allocation_constraints_and_optimality() {
    forall("allocation", 40, |rng| {
        let n_groups = [2usize, 4, 8][rng.below(3)];
        let per = 1 + rng.below(4);
        let n = n_groups * per;
        let w: Vec<f64> = (0..n).map(|_| rng.f64() + 0.001).collect();
        let a = allocate(&w, n_groups);
        a.validate().map_err(|e| e.to_string())?;
        // never worse than the identity assignment
        let id = Allocation::identity(n, n_groups);
        prop_assert!(
            a.objective(&w) <= id.objective(&w) + 1e-12,
            "worse than identity: {} > {}",
            a.objective(&w),
            id.objective(&w)
        );
        // objective is consistent with group workloads
        let target: f64 = w.iter().sum::<f64>() / n_groups as f64;
        let manual: f64 = a
            .group_workloads(&w)
            .iter()
            .map(|g| (g - target).abs())
            .sum();
        prop_assert!((manual - a.objective(&w)).abs() < 1e-12, "objective mismatch");
        Ok(())
    });
}

#[test]
fn prop_ct_bounds() {
    forall("ct-bounds", 40, |rng| {
        let tr = random_trace(rng);
        if tr.n_experts % 16 != 0 {
            return Ok(());
        }
        let layout = ExpertLayout::contiguous(tr.n_experts, 16, 4);
        let coalesced = A2aStats::evaluate(&tr, &layout, true);
        let raw = A2aStats::evaluate(&tr, &layout, false);
        // Appendix D: C_T == k without elision; <= k with elision; >= 1
        prop_assert!((raw.c_t - tr.top_k as f64).abs() < 1e-12, "raw C_T != k");
        prop_assert!(coalesced.c_t <= raw.c_t + 1e-12, "elision increased C_T");
        prop_assert!(tr.n_tokens() == 0 || coalesced.c_t >= 1.0, "C_T < 1");
        // elision never changes compute workload
        prop_assert!(
            coalesced.chiplet_token_slots == raw.chiplet_token_slots,
            "elision changed token slots"
        );
        Ok(())
    });
}

#[test]
fn prop_better_colocation_never_hurts_ct() {
    // moving a token's second expert onto the first expert's chiplet can
    // only reduce total replicas
    forall("colocation-monotone", 30, |rng| {
        let tr = random_trace(rng);
        if tr.n_experts % 16 != 0 || tr.top_k < 2 {
            return Ok(());
        }
        let contiguous = ExpertLayout::contiguous(tr.n_experts, 16, 4);
        // random permuted layout
        let perm = rng.permutation(tr.n_experts);
        let clusters: Vec<Vec<usize>> = perm
            .chunks(tr.n_experts / 16)
            .map(|c| c.to_vec())
            .collect();
        let scrambled = ExpertLayout::new(
            mozart::clustering::Clustering {
                clusters,
                n_experts: tr.n_experts,
            },
            mozart::allocation::Allocation::identity(16, 4),
            4,
        );
        let a = A2aStats::evaluate(&tr, &contiguous, true);
        let b = A2aStats::evaluate(&tr, &scrambled, true);
        // both bounded by k; no ordering guaranteed between arbitrary
        // layouts, but totals must be consistent
        prop_assert!(a.c_t <= tr.top_k as f64 + 1e-12, "a out of bound");
        prop_assert!(b.c_t <= tr.top_k as f64 + 1e-12, "b out of bound");
        prop_assert!(
            a.chiplet_token_slots.iter().sum::<u64>()
                == b.chiplet_token_slots.iter().sum::<u64>(),
            "layouts changed total compute"
        );
        Ok(())
    });
}

#[test]
fn prop_pareto_frontier_sound_complete_idempotent() {
    // the explorer's Pareto selection: no frontier point is dominated,
    // every dominated point is excluded (and witnessed by a frontier
    // member), and re-extracting the frontier of the frontier is a no-op.
    forall("pareto-frontier", 60, |rng| {
        let dims = 2 + rng.below(3);
        let n = 1 + rng.below(40);
        // discretized coordinates with a small jitter: plenty of dominance
        // chains and near-ties in the same point set
        let points = objective_cloud(rng, n, dims);
        let frontier = pareto::pareto_frontier(&points);
        prop_assert!(!frontier.is_empty(), "frontier empty on {n} points");
        for &m in &frontier {
            for (j, p) in points.iter().enumerate() {
                prop_assert!(
                    j == m || !pareto::dominates(p, &points[m]),
                    "frontier member {m} dominated by {j}"
                );
            }
        }
        for i in 0..points.len() {
            if !frontier.contains(&i) {
                prop_assert!(
                    frontier.iter().any(|&m| pareto::dominates(&points[m], &points[i])),
                    "excluded point {i} not dominated by any frontier member"
                );
            }
        }
        let members: Vec<Vec<f64>> = frontier.iter().map(|&m| points[m].clone()).collect();
        prop_assert!(
            pareto::pareto_frontier(&members).len() == members.len(),
            "frontier not idempotent"
        );
        Ok(())
    });
}

#[test]
fn prop_streaming_frontier_matches_batch_reduction() {
    // the guided search's incremental archive (pareto::Frontier::insert)
    // must end up exactly equal to the batch O(n^2) reduction over the same
    // point set, whatever the insertion order or duplicate structure
    forall("frontier-streaming", 60, |rng| {
        let n = 1 + rng.below(50);
        let dims = 2 + rng.below(3);
        let mut points = objective_cloud(rng, n, dims);
        if n >= 2 && rng.f64() < 0.3 {
            points[1] = points[0].clone(); // exact duplicates survive in both
        }
        let mut f = pareto::Frontier::new();
        for (i, p) in points.iter().enumerate() {
            f.insert(i, p);
        }
        let batch = pareto::pareto_frontier(&points);
        prop_assert!(
            f.keys() == batch,
            "streaming archive {:?} != batch frontier {:?}",
            f.keys(),
            batch
        );
        prop_assert!(f.len() == batch.len(), "archive size mismatch");
        // every archive member is genuinely non-dominated
        for (_, obj) in f.iter() {
            prop_assert!(
                points.iter().all(|p| !pareto::dominates(p, obj)),
                "archive kept a dominated point"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_non_dominated_sort_rank0_is_the_pareto_frontier() {
    // the NSGA-II sort's first front must be exactly the batch frontier,
    // the fronts must partition the index set, and every point of front
    // k > 0 must be dominated by some point of front k - 1
    forall("nds-rank0", 60, |rng| {
        let dims = 2 + rng.below(3);
        let n = 1 + rng.below(40);
        let mut points = objective_cloud(rng, n, dims);
        if n >= 2 && rng.f64() < 0.3 {
            points[1] = points[0].clone(); // exact duplicates share a front
        }
        let fronts = pareto::non_dominated_sort(&points);
        prop_assert!(!fronts.is_empty(), "no fronts on {n} points");
        prop_assert!(
            fronts[0] == pareto::pareto_frontier(&points),
            "front 0 != batch frontier"
        );
        let mut all: Vec<usize> = fronts.iter().flatten().copied().collect();
        all.sort_unstable();
        prop_assert!(
            all == (0..n).collect::<Vec<_>>(),
            "fronts do not partition the index set"
        );
        for k in 1..fronts.len() {
            for &i in &fronts[k] {
                prop_assert!(
                    fronts[k - 1]
                        .iter()
                        .any(|&j| pareto::dominates(&points[j], &points[i])),
                    "front-{k} point {i} not dominated from front {}",
                    k - 1
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_crowding_distance_is_permutation_invariant() {
    // crowding distance must be a function of a point's objective values
    // alone: permuting the input permutes the output identically, and exact
    // duplicates always share one distance
    forall("crowding-permutation", 60, |rng| {
        let dims = 2 + rng.below(3);
        let n = 1 + rng.below(30);
        let mut points = objective_cloud(rng, n, dims);
        if n >= 2 && rng.f64() < 0.4 {
            points[1] = points[0].clone();
        }
        let base = pareto::crowding_distance(&points);
        prop_assert!(base.len() == n, "one distance per point");
        prop_assert!(
            base.iter().all(|d| *d >= 0.0),
            "crowding distances must be non-negative"
        );
        let perm = rng.permutation(n);
        let permuted: Vec<Vec<f64>> = perm.iter().map(|&i| points[i].clone()).collect();
        let shuffled = pareto::crowding_distance(&permuted);
        for (pos, &i) in perm.iter().enumerate() {
            prop_assert!(
                shuffled[pos] == base[i],
                "distance changed under permutation at {i}: {} != {}",
                shuffled[pos],
                base[i]
            );
        }
        for i in 0..n {
            for j in 0..n {
                if points[i] == points[j] {
                    prop_assert!(
                        base[i] == base[j],
                        "duplicates {i},{j} got different distances"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_feasible_candidates_always_outrank_infeasible() {
    // the constrained-NSGA-II selection order: every feasible point
    // precedes every infeasible one (whatever their objectives), the
    // feasible head starts with the feasible subset's Pareto frontier, and
    // the infeasible tail is sorted by ascending violation
    forall("feasible-outranks", 60, |rng| {
        let dims = 2 + rng.below(3);
        let n = 2 + rng.below(30);
        let (points, violation) = constrained_objective_cloud(rng, n, dims);
        let order = pareto::constrained_selection_order(&points, &violation);
        prop_assert!(order.len() == n, "order must cover every point");
        let mut sorted = order.clone();
        sorted.sort_unstable();
        prop_assert!(
            sorted == (0..n).collect::<Vec<_>>(),
            "order is not a permutation"
        );
        let n_feasible = violation.iter().filter(|&&v| v == 0.0).count();
        for (pos, &i) in order.iter().enumerate() {
            prop_assert!(
                (violation[i] == 0.0) == (pos < n_feasible),
                "infeasible point {i} ranked inside the feasible prefix"
            );
        }
        // the feasible prefix leads with the feasible Pareto frontier
        let feasible: Vec<usize> = (0..n).filter(|&i| violation[i] == 0.0).collect();
        let fobjs: Vec<Vec<f64>> = feasible.iter().map(|&i| points[i].clone()).collect();
        let rank0: std::collections::BTreeSet<usize> = pareto::pareto_frontier(&fobjs)
            .into_iter()
            .map(|k| feasible[k])
            .collect();
        let head: std::collections::BTreeSet<usize> =
            order[..rank0.len()].iter().copied().collect();
        prop_assert!(
            head == rank0,
            "selection head {head:?} != feasible frontier {rank0:?}"
        );
        for w in order[n_feasible..].windows(2) {
            prop_assert!(
                violation[w[0]] <= violation[w[1]],
                "infeasible tail not sorted by violation"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_nsga2_without_crossover_reproduces_bit_identical_frontiers() {
    // NSGA-II with crossover disabled and the seeds fixed must walk the
    // exact same trajectory twice: same candidates, same cells, same
    // archive, same convergence curve (tiny model so this stays cheap)
    use mozart::config::{DramKind, Method, ModelId};
    use mozart::coordinator::explore::{parse_axes, ExploreConfig};
    use mozart::coordinator::search::{search, SearchConfig, SearchStrategy};
    let cfg = SearchConfig::new(
        ExploreConfig {
            axes: parse_axes("tiles=36:49:64,dram").expect("axes parse"),
            budget: 0,
            models: vec![ModelId::TinyMoE],
            methods: vec![Method::MozartC],
            scheds: vec![mozart::config::SchedPolicy::Streaming],
            seq_len: 64,
            dram: DramKind::Hbm2,
            iters: 1,
            seed: 23,
            threads: 0,
            eval: mozart::coordinator::cache::EvalOptions::default(),
        },
        SearchStrategy::Evolutionary {
            population: 3,
            generations: 3,
            crossover_rate: 0.0, // mutation-only
            mutation_rate: 0.5,
            seed: 23,
        },
    );
    let a = search(&cfg);
    let b = search(&cfg);
    assert_eq!(a.candidates.len(), b.candidates.len());
    for (x, y) in a.candidates.iter().zip(b.candidates.iter()) {
        assert_eq!(x.genome, y.genome);
        assert_eq!(x.label, y.label);
    }
    assert_eq!(a.archive, b.archive, "frontiers must be bit-identical");
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(b.cells.iter()) {
        assert_eq!(x.latency_s, y.latency_s);
        assert_eq!(x.energy_j, y.energy_j);
        assert_eq!(x.area_mm2, y.area_mm2);
    }
    for (x, y) in a.convergence.iter().zip(b.convergence.iter()) {
        assert_eq!(x.hypervolume, y.hypervolume);
        assert_eq!(x.archive_size, y.archive_size);
        assert_eq!(x.feasible, y.feasible);
    }
}

#[test]
fn prop_joint_frontier_respects_per_model_dominance() {
    // the multi-model joint objective is the elementwise worst case (max)
    // across per-model objective vectors. If candidate X dominates Y in
    // EVERY per-model slice, then X is at least as good as Y jointly: Y may
    // only survive on the joint frontier by tying X, never by beating it —
    // i.e. the joint frontier never keeps a point it shouldn't.
    forall("joint-frontier", 40, |rng| {
        let n_models = 2 + rng.below(3);
        let n = 2 + rng.below(25);
        let dims = 3;
        let per_model: Vec<Vec<Vec<f64>>> = (0..n_models)
            .map(|_| objective_cloud(rng, n, dims))
            .collect();
        let joint: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                (0..dims)
                    .map(|d| {
                        per_model
                            .iter()
                            .map(|m| m[i][d])
                            .fold(f64::NEG_INFINITY, f64::max)
                    })
                    .collect()
            })
            .collect();
        let joint_frontier = pareto::pareto_frontier(&joint);
        prop_assert!(!joint_frontier.is_empty(), "joint frontier empty");
        for x in 0..n {
            for y in 0..n {
                if x == y {
                    continue;
                }
                let everywhere = per_model
                    .iter()
                    .all(|m| pareto::dominates(&m[x], &m[y]));
                if !everywhere {
                    continue;
                }
                // weak joint dominance: x no worse than y on every objective
                prop_assert!(
                    joint[x].iter().zip(joint[y].iter()).all(|(a, b)| a <= b),
                    "per-model dominance did not carry to the joint objectives"
                );
                prop_assert!(
                    !pareto::dominates(&joint[y], &joint[x]),
                    "jointly, {y} dominates its per-model dominator {x}"
                );
                // and if the advantage survives the max, y is off the frontier
                if pareto::dominates(&joint[x], &joint[y]) {
                    prop_assert!(
                        !joint_frontier.contains(&y),
                        "joint frontier kept {y}, strictly dominated by {x}"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Random DAG plan for engine properties.
fn random_plan(rng: &mut Rng) -> Plan {
    let mut plan = Plan::new();
    let n_res = 1 + rng.below(4);
    for r in 0..n_res {
        plan.add_resource(format!("r{r}"));
    }
    let n = 2 + rng.below(60);
    for i in 0..n {
        let n_deps = rng.below(3.min(i + 1));
        let mut deps = Vec::new();
        for _ in 0..n_deps {
            deps.push(rng.below(i.max(1)));
        }
        deps.sort_unstable();
        deps.dedup();
        plan.add_task(TaskSpec {
            resource: if rng.f64() < 0.8 {
                Some(rng.below(n_res))
            } else {
                None
            },
            duration: rng.f64() * 10.0,
            deps,
            priority: rng.below(100) as i64 - 50,
            tag: Tag::Barrier,
            bytes: 0.0,
            flops: 0.0,
        });
    }
    plan
}

#[test]
fn prop_sim_engine_invariants() {
    forall("sim-engine", 60, |rng| {
        let plan = random_plan(rng);
        plan.validate().map_err(|e| e.to_string())?;
        let res = Simulator::run(&plan);
        // causality: every task starts after its deps finish
        for (i, t) in plan.tasks.iter().enumerate() {
            for &d in &t.deps {
                prop_assert!(
                    res.start[i] >= res.finish[d] - 1e-9,
                    "task {i} started before dep {d} finished"
                );
            }
            prop_assert!(
                (res.finish[i] - res.start[i] - t.duration).abs() < 1e-9,
                "task {i} duration distorted"
            );
        }
        // no resource over-subscription: busy time <= makespan
        for r in 0..plan.resource_names.len() {
            prop_assert!(
                res.resource_busy[r] <= res.makespan + 1e-9,
                "resource {r} over-subscribed"
            );
        }
        // makespan == max finish
        let maxf = res.finish.iter().cloned().fold(0.0f64, f64::max);
        prop_assert!((res.makespan - maxf).abs() < 1e-12, "makespan mismatch");
        // critical path duration <= makespan, and > 0 for nonempty plans
        let cp: f64 = res.critical_path.iter().map(|(_, v)| v).sum();
        prop_assert!(cp <= res.makespan + 1e-9, "critical path {cp} > makespan");
        Ok(())
    });
}

#[test]
fn prop_sim_is_deterministic() {
    forall("sim-deterministic", 20, |rng| {
        let plan = random_plan(rng);
        let a = Simulator::run(&plan);
        let b = Simulator::run(&plan);
        prop_assert!(a.makespan == b.makespan, "nondeterministic makespan");
        prop_assert!(a.finish == b.finish, "nondeterministic schedule");
        Ok(())
    });
}

#[test]
fn prop_serializing_resources_never_speeds_up() {
    // merging all tasks onto ONE resource cannot reduce the makespan
    forall("resource-monotone", 25, |rng| {
        let plan = random_plan(rng);
        let parallel = Simulator::run(&plan).makespan;
        let mut serial = plan.clone();
        for t in serial.tasks.iter_mut() {
            if t.resource.is_some() {
                t.resource = Some(0);
            }
        }
        let serialized = Simulator::run(&serial).makespan;
        prop_assert!(
            serialized >= parallel - 1e-9,
            "serializing sped things up: {serialized} < {parallel}"
        );
        Ok(())
    });
}

#[test]
fn prop_oracle_rejects_mutated_traces() {
    // soundness of the schedule-validity oracle: a genuine trace from any
    // policy validates, and every class of corruption — a distorted slot
    // time, a double dispatch, a makespan lie — is rejected
    use mozart::config::SchedPolicy;
    use mozart::sim::SimScratch;
    forall("oracle-soundness", 40, |rng| {
        let plan = random_plan(rng);
        let policy = SchedPolicy::ALL[rng.below(4)];
        let (_, trace) = Simulator::run_policy_traced(
            &plan,
            policy,
            rng.next_u64(),
            &mut SimScratch::new(),
        );
        trace.validate(&plan).map_err(|e| e.to_string())?;

        // slot-time distortion: start moves, finish does not, so either the
        // duration or the tightness invariant must trip
        let mut t = trace.clone();
        let victim = rng.below(plan.tasks.len());
        t.slots[victim].start += 1.0 + rng.f64();
        prop_assert!(t.validate(&plan).is_err(), "distorted slot accepted");

        // double dispatch breaks the placement permutation
        let mut t = trace.clone();
        t.order[1] = t.order[0];
        prop_assert!(t.validate(&plan).is_err(), "double dispatch accepted");

        // a makespan lie fails the independent critical-path recomputation
        let mut t = trace.clone();
        t.makespan += 1.0;
        prop_assert!(t.validate(&plan).is_err(), "makespan lie accepted");
        Ok(())
    });
}

#[test]
fn prop_policies_conserve_work() {
    // a dispatch policy reorders work but never changes it: per-tag busy
    // seconds are summed by the engine in fixed task-id order, so they are
    // bit-identical across all four policies on any plan
    use mozart::config::SchedPolicy;
    use mozart::sim::SimScratch;
    forall("policy-work-conservation", 30, |rng| {
        let plan = random_plan(rng);
        let seed = rng.next_u64();
        let mut scratch = SimScratch::new();
        let reference =
            Simulator::run_policy(&plan, SchedPolicy::Streaming, seed, &mut scratch);
        for policy in SchedPolicy::ALL {
            let res = Simulator::run_policy(&plan, policy, seed, &mut scratch);
            prop_assert!(
                res.tag_busy == reference.tag_busy,
                "{} changed total per-tag busy time",
                policy.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_never_beats_the_dependency_critical_path() {
    // resource contention can only add to the dependency-only longest path,
    // never subtract: every policy's makespan respects the DP lower bound
    use mozart::config::SchedPolicy;
    use mozart::sim::SimScratch;
    forall("makespan-lower-bound", 30, |rng| {
        let plan = random_plan(rng);
        // deps always point backwards in random_plan, so task-id order is
        // topological and one forward DP pass computes the bound
        let mut lb = vec![0.0f64; plan.tasks.len()];
        let mut bound = 0.0f64;
        for (i, t) in plan.tasks.iter().enumerate() {
            let longest = t.deps.iter().map(|&d| lb[d]).fold(0.0f64, f64::max);
            lb[i] = longest + t.duration;
            bound = bound.max(lb[i]);
        }
        let mut scratch = SimScratch::new();
        for policy in SchedPolicy::ALL {
            let res = Simulator::run_policy(&plan, policy, 7, &mut scratch);
            prop_assert!(
                res.makespan >= bound - 1e-9,
                "{}: makespan {} < dependency bound {bound}",
                policy.name(),
                res.makespan
            );
        }
        Ok(())
    });
}

#[test]
fn prop_partition_policies_conserve_the_wafer() {
    // every share-vector generator (even, weighted, random, and the search
    // operators mutate/crossover) hands out exactly the wafer's groups with
    // every tenant kept alive, and the derived slices conserve DRAM stacks
    // and attention tiles against the parent
    use mozart::config::{DramKind, HwConfig, Method, ModelId};
    use mozart::coordinator::tenants::{
        crossover_shares, even_shares, mutate_shares, random_shares, weighted_shares, TenantKind,
        TenantSpec,
    };
    forall("tenant-shares-conserve", 60, |rng| {
        let parent = HwConfig::mozart_wafer(DramKind::Hbm2);
        let n = 1 + rng.below(parent.n_groups);
        let specs: Vec<TenantSpec> = (0..n)
            .map(|i| TenantSpec {
                model: ModelId::TinyMoE,
                kind: if i % 2 == 0 {
                    TenantKind::Train {
                        method: Method::MozartC,
                        weight: 0.25 + rng.f64() * 4.0,
                    }
                } else {
                    TenantKind::Serve {
                        load_rps: 10.0 + rng.f64() * 200.0,
                        slo_ms: 20.0 + rng.f64() * 80.0,
                    }
                },
            })
            .collect();
        let mut op_rng = Rng::new(rng.next_u64());
        let mut mutated = random_shares(&mut op_rng, n, parent.n_groups);
        mutate_shares(&mut op_rng, &mut mutated);
        let pa = random_shares(&mut op_rng, n, parent.n_groups);
        let pb = random_shares(&mut op_rng, n, parent.n_groups);
        let child = crossover_shares(&mut op_rng, &pa, &pb, parent.n_groups);
        for shares in [
            even_shares(n, &parent),
            weighted_shares(&specs, &parent),
            random_shares(&mut op_rng, n, parent.n_groups),
            mutated,
            child,
        ] {
            prop_assert!(shares.len() == n, "share arity {shares:?} for {n} tenants");
            let total: usize = shares.iter().sum();
            prop_assert!(
                total == parent.n_groups,
                "no-idle policy leaked groups: {shares:?} sums to {total}"
            );
            prop_assert!(
                shares.iter().all(|&s| s >= 1),
                "a tenant was starved of groups: {shares:?}"
            );
            let slices = parent.partition_slices(&shares)?;
            let stacks: usize = slices.iter().map(|s| s.group_dram_stacks).sum();
            let tiles: usize = slices.iter().map(|s| s.attn_tiles).sum();
            prop_assert!(
                stacks == parent.mem.group_dram_stacks,
                "DRAM stacks not conserved: {stacks} != {}",
                parent.mem.group_dram_stacks
            );
            prop_assert!(
                tiles == parent.attn_chiplet.tiles,
                "attention tiles not conserved: {tiles} != {}",
                parent.attn_chiplet.tiles
            );
            prop_assert!(
                slices.iter().all(|s| s.group_dram_stacks >= 1 && s.attn_tiles >= 1),
                "a slice starves a resource class: {slices:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_slo_greedy_never_worse_than_even_on_worst_violation() {
    // slo-greedy starts from the even partition and only accepts moves that
    // strictly improve (worst violation, -throughput) lexicographically, so
    // its worst-tenant SLO violation can never exceed even's
    use mozart::config::ModelId;
    use mozart::coordinator::tenants::{
        self, PartitionPolicy, TenantKind, TenantSpec, TenantsConfig,
    };
    forall("slo-greedy-dominates-even", 2, |rng| {
        let specs = vec![
            TenantSpec {
                model: ModelId::TinyMoE,
                kind: TenantKind::Serve {
                    load_rps: 40.0 + rng.f64() * 120.0,
                    slo_ms: 5.0 + rng.f64() * 45.0,
                },
            },
            TenantSpec {
                model: ModelId::TinyMoE,
                kind: TenantKind::Serve {
                    load_rps: 40.0 + rng.f64() * 120.0,
                    slo_ms: 5.0 + rng.f64() * 45.0,
                },
            },
        ];
        let cfg = TenantsConfig {
            tenants: specs,
            policies: vec![PartitionPolicy::Even, PartitionPolicy::SloGreedy],
            seq_len: 64,
            duration_s: 0.5,
            iters: 1,
            seed: rng.next_u64(),
            threads: 1,
            ..TenantsConfig::paper_default()
        };
        let out = tenants::run(&cfg);
        let even = &out.policies[0];
        let greedy = &out.policies[1];
        prop_assert!(
            greedy.objectives[0] <= even.objectives[0],
            "slo-greedy worst violation {} > even's {}",
            greedy.objectives[0],
            even.objectives[0]
        );
        Ok(())
    });
}

#[test]
fn prop_seeded_share_operators_are_bit_reproducible() {
    // identically-seeded mutation/crossover streams replay identically —
    // the search gene operators are pure functions of (seed, parents)
    use mozart::config::{DramKind, HwConfig};
    use mozart::coordinator::tenants::{crossover_shares, mutate_shares, random_shares};
    forall("share-operators-reproducible", 40, |rng| {
        let parent = HwConfig::mozart_wafer(DramKind::Hbm2);
        let n = 1 + rng.below(parent.n_groups);
        let seed = rng.next_u64();
        let replay = |seed: u64| {
            let mut r = Rng::new(seed);
            let mut s = random_shares(&mut r, n, parent.n_groups);
            for _ in 0..4 {
                mutate_shares(&mut r, &mut s);
            }
            let other = random_shares(&mut r, n, parent.n_groups);
            let child = crossover_shares(&mut r, &s, &other, parent.n_groups);
            (s, other, child)
        };
        prop_assert!(
            replay(seed) == replay(seed),
            "seeded share operators diverged on replay (seed {seed})"
        );
        Ok(())
    });
}
