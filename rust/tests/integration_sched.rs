//! Pluggable-scheduler contracts: the default `streaming` policy is
//! bit-identical to the plain sweep path on the full Table 3 grid, every
//! policy produces a schedule the validity oracle accepts on every Table 3
//! cell, seeded tie-breaking makes each policy reproducible across thread
//! counts and repeated runs, and HEFT's upward-rank ordering strictly beats
//! FIFO list scheduling on the wide-DAG fixture it was designed for.
//!
//! (Running any policy in these debug-build tests additionally routes every
//! simulated iteration through the oracle inside the engine itself — the
//! explicit `validate` calls below are the direct, non-`debug_assertions`
//! evidence.)

use mozart::config::SchedPolicy;
use mozart::coordinator::sweep::{
    cell_config_sched, run_cells_sched, run_cells_with, table3_cells, SweepOptions,
};
use mozart::coordinator::layouts_for;
use mozart::pipeline::{PlanCache, StepWorkload};
use mozart::sim::{Plan, SimScratch, Simulator, Tag, TaskSpec};
use mozart::trace::TraceGen;
use mozart::util::rng::Rng;

fn opts(threads: usize) -> SweepOptions {
    SweepOptions { threads }
}

/// The default policy must be invisible: `run_cells_sched(.., Streaming, ..)`
/// reproduces the plain (pre-refactor) sweep path bit for bit on the full
/// Table 3 grid — latency, C_T, and the per-tag busy breakdown.
#[test]
fn streaming_is_bit_identical_to_the_default_sweep_on_table3() {
    let cells = table3_cells();
    let plain = run_cells_with(&cells, 1, 7, opts(0));
    let streaming = run_cells_sched(&cells, 1, 7, SchedPolicy::Streaming, opts(0));
    assert_eq!(plain.len(), streaming.len());
    for (a, b) in plain.iter().zip(streaming.iter()) {
        assert_eq!(
            a.result.latency.to_bits(),
            b.result.latency.to_bits(),
            "{:?}/{:?}: streaming diverged from the default path",
            a.cell.model,
            a.cell.method
        );
        assert_eq!(a.result.c_t.to_bits(), b.result.c_t.to_bits());
        assert_eq!(a.result.tag_busy, b.result.tag_busy);
    }
}

/// The schedule-validity oracle accepts every policy's schedule on every
/// Table 3 cell: build each cell's real step plan once, then run all four
/// policies traced over it and hand each trace to `ScheduleTrace::validate`.
#[test]
fn every_policy_passes_the_oracle_on_every_table3_cell() {
    let mut scratch = SimScratch::new();
    for cell in table3_cells() {
        let cfg = cell_config_sched(cell, 1, 7, SchedPolicy::Streaming);
        let gen = TraceGen::for_model(&cfg.model, cfg.seed);
        let layouts = layouts_for(&cfg, &gen);
        let mut cache = PlanCache::new(&cfg, &layouts);
        // the first training-step workload, exactly as run_experiment draws it
        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        let mut step_rng = rng.fork(0);
        let w =
            StepWorkload::sample(&cfg, &gen, &layouts, cfg.method.efficient_a2a, &mut step_rng);
        let plan = cache.rebuild(&w);
        for policy in SchedPolicy::ALL {
            let (res, trace) =
                Simulator::run_policy_traced(plan, policy, cfg.seed, &mut scratch);
            trace.validate(plan).unwrap_or_else(|e| {
                panic!(
                    "{:?}/{:?}: oracle rejected the {} schedule: {e}",
                    cell.model,
                    cell.method,
                    policy.name()
                )
            });
            assert!(
                res.makespan.is_finite() && res.makespan > 0.0,
                "{:?}/{:?}/{}: degenerate makespan {}",
                cell.model,
                cell.method,
                policy.name(),
                res.makespan
            );
            assert_eq!(res.makespan.to_bits(), trace.makespan.to_bits());
        }
    }
}

/// Seeded tie-breaking means the executor topology cannot leak into the
/// schedule: every policy produces bit-identical sweep results sequentially,
/// under the parallel executor, and across repeated runs.
#[test]
fn every_policy_is_reproducible_across_thread_counts() {
    let cells = table3_cells();
    for policy in SchedPolicy::ALL {
        let seq = run_cells_sched(&cells, 1, 7, policy, opts(1));
        let par = run_cells_sched(&cells, 1, 7, policy, opts(4));
        let again = run_cells_sched(&cells, 1, 7, policy, opts(4));
        for ((a, b), c) in seq.iter().zip(par.iter()).zip(again.iter()) {
            assert_eq!(
                a.result.latency.to_bits(),
                b.result.latency.to_bits(),
                "{}: parallel executor changed the schedule on {:?}/{:?}",
                policy.name(),
                a.cell.model,
                a.cell.method
            );
            assert_eq!(
                b.result.latency.to_bits(),
                c.result.latency.to_bits(),
                "{}: repeated run diverged on {:?}/{:?}",
                policy.name(),
                a.cell.model,
                a.cell.method
            );
            assert_eq!(a.result.tag_busy, b.result.tag_busy);
        }
    }
}

/// HEFT's upward-rank priority must beat plain FIFO on the wide-DAG shape it
/// exists for: several short independent sources queued (by id order) ahead
/// of the head of a long dependent chain on a second resource. List burns
/// the sources first and serializes behind the chain; HEFT dispatches the
/// chain head immediately.
#[test]
fn heft_beats_list_on_a_wide_dag() {
    let spec = |resource: Option<usize>, duration: f64, deps: &[usize]| TaskSpec {
        resource,
        duration,
        deps: deps.to_vec(),
        priority: 0,
        tag: Tag::Barrier,
        bytes: 0.0,
        flops: 0.0,
    };
    let mut p = Plan::new();
    let sources = p.add_resource("sources");
    let chain_res = p.add_resource("chain");
    for _ in 0..4 {
        p.add_task(spec(Some(sources), 1.0, &[]));
    }
    let head = p.add_task(spec(Some(sources), 1.0, &[]));
    let mut prev = head;
    for _ in 0..10 {
        prev = p.add_task(spec(Some(chain_res), 1.0, &[prev]));
    }

    let mut scratch = SimScratch::new();
    let list = Simulator::run_policy(&p, SchedPolicy::List, 7, &mut scratch);
    let heft = Simulator::run_policy(&p, SchedPolicy::Heft, 7, &mut scratch);
    assert!(
        heft.makespan < list.makespan,
        "HEFT {} did not beat list {} on the wide DAG",
        heft.makespan,
        list.makespan
    );
    // the exact analytical makespans: FIFO waits out all five source slots
    // (5s) before the 10-task chain; HEFT starts the chain after 1s
    assert_eq!(list.makespan, 15.0);
    assert_eq!(heft.makespan, 11.0);
}
