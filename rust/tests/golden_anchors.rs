//! Golden-anchor regression tests: snapshot the Table 2 anchor platform
//! metrics and all four Table 3 method cells (latency / energy / area at
//! fixed precision) against checked-in expected values in
//! `tests/golden/*.json`, so simulator drift is caught by `cargo test`
//! instead of only by eyeballing `mozart report` output.
//!
//! Protocol (see `tests/golden/README.md`):
//! - a missing golden file is created from the current output and the test
//!   passes with a notice — commit the file to arm the check;
//! - `MOZART_BLESS=1 cargo test --test golden_anchors` re-blesses every
//!   snapshot after an intentional recalibration;
//! - values are compared as strings at 7 significant digits, so the check
//!   is immune to harmless formatting churn but catches any real change in
//!   the simulated numbers.

use std::path::{Path, PathBuf};

use mozart::arch::area::hw_metrics;
use mozart::config::{DramKind, HwConfig, Method, ModelConfig, ModelId};
use mozart::coordinator::run_experiment;
use mozart::coordinator::sweep::{cell_config, Cell};
use mozart::util::json::Json;

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Fixed-precision rendering: 7 significant digits in scientific notation —
/// tight enough that any real simulator/model drift changes the string,
/// uniform across the magnitudes involved (seconds to mm²).
fn sig(v: f64) -> String {
    format!("{v:.6e}")
}

/// Compare `current` against the checked-in snapshot, or (re)create the
/// snapshot when it is missing or `MOZART_BLESS=1` is set.
fn check_or_bless(name: &str, current: &Json) {
    let dir = golden_dir();
    let path = dir.join(name);
    let rendered = current.render_pretty();
    // exactly `MOZART_BLESS=1` re-blesses — anything else (unset, empty,
    // `0`) must compare, so an exported-but-disabled variable can never
    // silently overwrite the baselines
    let bless = std::env::var("MOZART_BLESS").as_deref() == Ok("1");
    if bless || !path.exists() {
        std::fs::create_dir_all(&dir).expect("create tests/golden");
        std::fs::write(&path, &rendered).expect("write golden snapshot");
        if !bless {
            eprintln!(
                "golden: {} did not exist — created it from the current simulator \
                 output; commit it so future runs catch drift",
                path.display()
            );
        }
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden snapshot");
    assert_eq!(
        expected, rendered,
        "golden anchor drift in {name}: the simulator's Table 2/3 numbers no \
         longer match the checked-in snapshot. If this change is intentional \
         (e.g. a recalibration), re-bless with `MOZART_BLESS=1 cargo test \
         --test golden_anchors` and commit the updated file."
    );
}

/// Table 2 anchor: the analytic 28nm area/power metrics of every paper
/// model's platform (the point `mozart explore` always evaluates as
/// candidate 0).
#[test]
fn golden_table2_anchor_platforms() {
    let rows: Vec<Json> = ModelId::PAPER_MODELS
        .iter()
        .map(|&id| {
            let m = ModelConfig::preset(id);
            let hw = HwConfig::paper_for_model(id, DramKind::Hbm2);
            let x = hw_metrics(&m, &hw);
            Json::obj([
                ("model", Json::str(id.name())),
                ("area_mm2", Json::str(sig(x.total_area_mm2))),
                ("power_kw", Json::str(sig(x.total_power_kw))),
                ("dram_bw_gbps", Json::str(sig(x.dram_bw_gbps))),
                ("nop_link_bw_gbps", Json::str(sig(x.nop_link_bw_gbps))),
            ])
        })
        .collect();
    check_or_bless(
        "table2_anchors.json",
        &Json::obj([
            ("snapshot", Json::str("table2_anchor_platforms")),
            ("precision", Json::str("7 significant digits")),
            ("rows", Json::Arr(rows)),
        ]),
    );
}

/// Table 3 method cells: the Table 2 anchor platform (Qwen3, seq 256, HBM2,
/// seed 7) simulated under each of the four ablation columns.
#[test]
fn golden_table3_method_cells() {
    let rows: Vec<Json> = Method::ALL
        .iter()
        .map(|&method| {
            let cell = Cell {
                model: ModelId::Qwen3_30B_A3B,
                method,
                seq_len: 256,
                dram: DramKind::Hbm2,
            };
            let cfg = cell_config(cell, 1, 7);
            let r = run_experiment(&cfg);
            let m = hw_metrics(&cfg.model, &cfg.hw);
            Json::obj([
                ("model", Json::str(cell.model.name())),
                ("method", Json::str(method.name())),
                ("latency_s", Json::str(sig(r.latency))),
                ("energy_j_per_step", Json::str(sig(r.energy.total_j()))),
                ("area_mm2", Json::str(sig(m.total_area_mm2))),
                ("c_t", Json::str(sig(r.c_t))),
            ])
        })
        .collect();
    check_or_bless(
        "table3_methods.json",
        &Json::obj([
            ("snapshot", Json::str("table3_method_cells")),
            ("workload", Json::str("qwen3 seq=256 dram=HBM2 iters=1 seed=7")),
            ("precision", Json::str("7 significant digits")),
            ("rows", Json::Arr(rows)),
        ]),
    );
}

/// The snapshots above are only meaningful if a cell re-simulation is
/// bit-reproducible — assert that here so a golden failure always means
/// drift, never flakiness.
#[test]
fn golden_inputs_are_deterministic() {
    let cell = Cell {
        model: ModelId::Qwen3_30B_A3B,
        method: Method::MozartC,
        seq_len: 256,
        dram: DramKind::Hbm2,
    };
    let a = run_experiment(&cell_config(cell, 1, 7));
    let b = run_experiment(&cell_config(cell, 1, 7));
    assert_eq!(sig(a.latency), sig(b.latency));
    assert_eq!(sig(a.energy.total_j()), sig(b.energy.total_j()));
    assert_eq!(sig(a.c_t), sig(b.c_t));
}
