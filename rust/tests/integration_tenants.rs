//! Multi-tenant partitioning contracts at the integration level: the
//! partition-isolation oracle rejects every corruption class with its own
//! clause (mutation tests), a single tenant owning the whole wafer is
//! bit-identical to the un-partitioned simulate / serve paths, identical
//! tenants on symmetric halves measure identically, and the policy sweep
//! is bit-identical across worker-thread counts.

use mozart::config::{DramKind, HwConfig, Method, ModelId};
use mozart::coordinator::cache::EvalSession;
use mozart::coordinator::run_experiment;
use mozart::coordinator::serve::{serve_cell_eval, ServeEvalSpec};
use mozart::coordinator::tenants::{
    self, build_trace, tenant_base_config, PartitionEval, PartitionPolicy, PartitionTrace,
    TenantKind, TenantMetrics, TenantSpec, TenantsConfig,
};
use mozart::trace::arrivals::{ArrivalProcess, RequestShape};

fn train_spec(weight: f64) -> TenantSpec {
    TenantSpec {
        model: ModelId::TinyMoE,
        kind: TenantKind::Train {
            method: Method::MozartC,
            weight,
        },
    }
}

fn serve_spec(load_rps: f64, slo_ms: f64) -> TenantSpec {
    TenantSpec {
        model: ModelId::TinyMoE,
        kind: TenantKind::Serve { load_rps, slo_ms },
    }
}

fn tiny(tenants: Vec<TenantSpec>, policies: Vec<PartitionPolicy>, threads: usize) -> TenantsConfig {
    TenantsConfig {
        tenants,
        policies,
        seq_len: 64,
        duration_s: 0.5,
        iters: 1,
        seed: 13,
        threads,
        ..TenantsConfig::paper_default()
    }
}

/// A structurally valid two-tenant trace built without any simulation:
/// synthetic per-tenant metrics over the real wafer's `[2, 2]` slices.
/// Every mutation test starts from this trace (asserted valid first) and
/// corrupts exactly one clause.
fn synthetic_trace() -> (HwConfig, PartitionTrace) {
    let parent = HwConfig::mozart_wafer(DramKind::Hbm2);
    let cfg = tiny(
        vec![train_spec(1.0), serve_spec(80.0, 50.0)],
        vec![PartitionPolicy::Even],
        1,
    );
    let shares = vec![2usize, 2];
    let slices = parent.partition_slices(&shares).expect("realizable");
    let tenants: Vec<TenantMetrics> = cfg
        .tenants
        .iter()
        .zip(slices.iter())
        .map(|(spec, slice)| TenantMetrics {
            label: spec.label(),
            kind: "synthetic",
            groups: slice.groups,
            latency_ms: 1.0,
            p99_ms: 2.0,
            goodput_rps: 10.0,
            slo_ms: 50.0,
            slo_violation: 0.0,
            tokens_per_s: 100.0,
            power_w: 120.0,
        })
        .collect();
    let eval = PartitionEval {
        shares: shares.clone(),
        slices,
        tenants,
        objectives: [0.0, -200.0, 240.0],
        power_w: 240.0,
        feasible: true,
    };
    let mut cfg = cfg;
    cfg.budget_w = 500.0;
    let trace = build_trace("synthetic", &cfg, &parent, &eval);
    trace.validate(&parent).expect("uncorrupted trace is valid");
    (parent, trace)
}

fn rejects_with(trace: &PartitionTrace, parent: &HwConfig, needle: &str) {
    let err = trace
        .validate(parent)
        .expect_err("corrupted trace must be rejected")
        .to_string();
    assert!(
        err.contains(needle),
        "expected the `{needle}` clause to fire, got: {err}"
    );
}

/// Mutation 1: a chiplet pushed into a second tenant's assignment trips
/// the exclusive-assignment clause.
#[test]
fn oracle_rejects_a_double_assigned_chiplet() {
    let (parent, mut tr) = synthetic_trace();
    let stolen = tr.assignments[1].chiplets[0];
    tr.assignments[0].chiplets.push(stolen);
    rejects_with(&tr, &parent, "more than one tenant");
}

/// Mutation 2: swapping one chiplet between the tenants (owner map kept
/// consistent, so the exclusivity clause stays quiet) breaks the
/// contiguous whole-group NoP-subtree requirement.
#[test]
fn oracle_rejects_a_non_contiguous_partition() {
    let (parent, mut tr) = synthetic_trace();
    let a = *tr.assignments[0].chiplets.last().unwrap();
    let b = tr.assignments[1].chiplets[0];
    *tr.assignments[0].chiplets.last_mut().unwrap() = b;
    tr.assignments[1].chiplets[0] = a;
    tr.chiplet_owner[a] = Some(1);
    tr.chiplet_owner[b] = Some(0);
    rejects_with(&tr, &parent, "contiguous");
}

/// Mutation 3: inflating one slice's DRAM stacks breaks resource
/// conservation against the parent wafer.
#[test]
fn oracle_rejects_resource_sum_drift() {
    let (parent, mut tr) = synthetic_trace();
    tr.assignments[0].slice.group_dram_stacks += 1;
    rejects_with(&tr, &parent, "conservation violated");
}

/// Mutation 4: shrinking the budget below the aggregate draw trips the
/// package power clause.
#[test]
fn oracle_rejects_power_over_budget() {
    let (parent, mut tr) = synthetic_trace();
    tr.budget_w = tr.power_w / 2.0;
    rejects_with(&tr, &parent, "exceeds the package power budget");
}

/// Mutation 5: an assignment claiming the wrong tenant index is a stale
/// tenant id.
#[test]
fn oracle_rejects_a_stale_tenant_id() {
    let (parent, mut tr) = synthetic_trace();
    tr.assignments[1].tenant = 7;
    rejects_with(&tr, &parent, "stale tenant id");
}

/// Differential: one training tenant owning 100% of the wafer carves a
/// fingerprint-identical platform, so its latency is bit-identical to the
/// un-partitioned `run_experiment` path.
#[test]
fn single_train_tenant_reproduces_the_unpartitioned_simulation() {
    let cfg = tiny(vec![train_spec(1.0)], vec![PartitionPolicy::Even], 1);
    let out = tenants::run(&cfg);
    assert_eq!(out.points.len(), 1);
    let point = &out.points[0];
    assert_eq!(point.shares, vec![out.parent.n_groups]);
    let trace = point.trace.as_ref().expect("feasible point carries a trace");
    trace.validate(&out.parent).expect("oracle");

    let base = tenant_base_config(&cfg.tenants[0], &out.parent, &cfg);
    let r = run_experiment(&base);
    assert_eq!(
        point.tenants[0].latency_ms.to_bits(),
        (r.latency * 1e3).to_bits(),
        "whole-wafer tenant latency diverged from run_experiment"
    );
    assert_eq!(
        point.tenants[0].power_w.to_bits(),
        r.energy.mean_power_w(r.latency).to_bits()
    );
}

/// Differential: one serving tenant owning 100% of the wafer reproduces
/// the `serve_cell_eval` search path bit-identically — same service
/// model, same seeded arrival stream, same measured p99 and goodput.
#[test]
fn single_serve_tenant_reproduces_the_unpartitioned_serving_path() {
    let cfg = tiny(vec![serve_spec(80.0, 50.0)], vec![PartitionPolicy::Even], 1);
    let out = tenants::run(&cfg);
    assert_eq!(out.points.len(), 1);
    let t = &out.points[0].tenants[0];

    let base = tenant_base_config(&cfg.tenants[0], &out.parent, &cfg);
    let session = EvalSession::new(cfg.eval.clone());
    let mut pool = session.new_pool();
    let mut ctx = session.ctx(&mut pool);
    let spec = ServeEvalSpec {
        arrivals: ArrivalProcess::Poisson { rate: 80.0 },
        shape: RequestShape::default(),
        duration_s: cfg.duration_s,
        slo_ms: 50.0,
        params: cfg.params.clone(),
    };
    let m = serve_cell_eval(|ec| ctx.run(ec).latency, &base, &spec);
    assert_eq!(
        t.p99_ms.to_bits(),
        m.p99_ms.to_bits(),
        "whole-wafer serving tenant p99 diverged from serve_cell_eval"
    );
    assert_eq!(t.goodput_rps.to_bits(), m.goodput_rps.to_bits());
}

/// Two identical serving tenants on the symmetric halves of the wafer see
/// fingerprint-identical platforms and the same seeded traffic, so their
/// per-tenant metrics are identical.
#[test]
fn identical_tenants_on_symmetric_halves_measure_identically() {
    let cfg = tiny(
        vec![serve_spec(60.0, 50.0), serve_spec(60.0, 50.0)],
        vec![PartitionPolicy::Even],
        1,
    );
    let out = tenants::run(&cfg);
    let point = &out.points[0];
    assert_eq!(point.shares, vec![2, 2]);
    assert_eq!(
        point.tenants[0], point.tenants[1],
        "symmetric tenants must be indistinguishable"
    );
}

/// The whole policy sweep is bit-identical across worker-thread counts:
/// per-tenant evaluations are seeded by the tenant, not by scheduling
/// order, so `--threads` affects wall-clock only.
#[test]
fn tenants_sweep_is_bit_identical_across_threads() {
    let specs = vec![train_spec(1.0), serve_spec(60.0, 50.0)];
    let policies = vec![
        PartitionPolicy::Even,
        PartitionPolicy::Weighted,
        PartitionPolicy::SloGreedy,
    ];
    let seq = tenants::run(&tiny(specs.clone(), policies.clone(), 1));
    let par = tenants::run(&tiny(specs, policies, 4));
    assert_eq!(seq.points.len(), par.points.len());
    for (x, y) in seq.points.iter().zip(par.points.iter()) {
        assert_eq!(x.shares, y.shares);
        assert_eq!(x.feasible, y.feasible);
        assert_eq!(x.power_w.to_bits(), y.power_w.to_bits());
        for k in 0..3 {
            assert_eq!(x.objectives[k].to_bits(), y.objectives[k].to_bits());
        }
        assert_eq!(x.tenants, y.tenants, "per-tenant metrics diverged");
    }
    assert_eq!(seq.frontier, par.frontier);
    for (x, y) in seq.policies.iter().zip(par.policies.iter()) {
        assert_eq!(x.policy, y.policy);
        assert_eq!(x.shares, y.shares);
    }
}
