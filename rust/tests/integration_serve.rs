//! Serving-simulator contracts at the integration level: the continuous
//! queueing engine reproduces the closed-form M/D/1 mean sojourn
//! (Pollaczek–Khinchine) at low utilization, every saturation-curve point
//! produced by a real `serve::run` satisfies Little's law to within 1%,
//! seeded sweeps are bit-identical across worker-thread counts, and the
//! guided search runs end to end under a serving objective
//! (`--objective p99`), attaching serving metrics to every candidate.

use mozart::config::{DramKind, Method, ModelId, SchedPolicy};
use mozart::coordinator::cache::EvalOptions;
use mozart::coordinator::explore::{parse_axes, ExploreConfig};
use mozart::coordinator::search::{search, Objective, SearchConfig, SearchStrategy};
use mozart::coordinator::serve::{self, ServeConfig, ServeEvalSpec};
use mozart::sim::serve::{simulate_serve, BatchClose, ServeParams, ServiceModel};
use mozart::trace::arrivals::{ArrivalProcess, RequestShape};

/// M/D/1 differential: with deterministic service time `D`, a batch-close
/// policy of `size:1` (each request served alone, FIFO, one server), an
/// unbounded queue, and Poisson arrivals at utilization `rho = lambda*D`,
/// Pollaczek–Khinchine gives the exact mean queueing delay
/// `Wq = rho*D / (2*(1 - rho))`, so the mean sojourn is `W = D + Wq`.
/// The engine is a general dynamic-batching simulator, not a formula —
/// agreement here is a differential check of its whole timing core. At
/// ~18k seeded requests the CLT noise on the sample mean is well under
/// 1% of `W`, so a 5% tolerance leaves a wide margin.
#[test]
fn low_rho_sojourn_matches_pollaczek_khinchine() {
    let d = 0.005; // 5 ms deterministic service
    let rho = 0.3;
    let arrivals = ArrivalProcess::Poisson { rate: rho / d }; // 60 req/s
    let shape = RequestShape::fixed(256, 0); // one prefill job, no decode
    let requests = arrivals.generate(300.0, &shape, 42);
    assert!(requests.len() > 10_000, "need a large sample for the mean");

    let model = ServiceModel::constant(d);
    let params = ServeParams {
        close: BatchClose::Size(1),
        ..ServeParams::default()
    };
    let trace = simulate_serve(&requests, &model, &params);
    trace.validate(&model).expect("queueing-invariant oracle");

    let spans = trace.completed_spans();
    assert_eq!(spans.len(), requests.len(), "uncapped queue drops nothing");
    let mean_w = spans.iter().map(|&(a, f)| f - a).sum::<f64>() / spans.len() as f64;
    let w_pk = d + rho * d / (2.0 * (1.0 - rho));
    let rel = (mean_w - w_pk).abs() / w_pk;
    assert!(
        rel < 0.05,
        "mean sojourn {mean_w:.6} s vs Pollaczek–Khinchine {w_pk:.6} s (rel err {rel:.4})"
    );
}

/// The M/D/1 agreement must degrade gracefully, not accidentally: at a
/// higher utilization the measured sojourn still sits above the batch-1
/// lower bound `D` and grows with `rho` (queueing delay is monotone in
/// offered load for a fixed service time).
#[test]
fn sojourn_grows_with_utilization() {
    let d = 0.005;
    let shape = RequestShape::fixed(256, 0);
    let model = ServiceModel::constant(d);
    let params = ServeParams {
        close: BatchClose::Size(1),
        ..ServeParams::default()
    };
    let mean_at = |rho: f64| {
        let reqs = ArrivalProcess::Poisson { rate: rho / d }.generate(120.0, &shape, 7);
        let trace = simulate_serve(&reqs, &model, &params);
        trace.validate(&model).expect("oracle");
        let spans = trace.completed_spans();
        spans.iter().map(|&(a, f)| f - a).sum::<f64>() / spans.len() as f64
    };
    let w_low = mean_at(0.2);
    let w_high = mean_at(0.7);
    assert!(w_low >= d && w_high >= d, "sojourn below service time");
    assert!(
        w_high > w_low,
        "sojourn must grow with load: W(0.7)={w_high:.6} <= W(0.2)={w_low:.6}"
    );
}

fn tiny_serve(threads: usize) -> ServeConfig {
    ServeConfig {
        arrivals: ArrivalProcess::Poisson { rate: 120.0 },
        duration_s: 1.0,
        loads: vec![0.5, 1.0, 1.5],
        iters: 1,
        seed: 23,
        threads,
        ..ServeConfig::paper_default()
    }
}

/// Acceptance gate: every point on a real saturation curve passes the
/// trace oracle (checked inside `measure_point`, which panics otherwise)
/// and closes Little's law `L = lambda_eff * W` to within 1%.
#[test]
fn every_saturation_point_obeys_littles_law_within_one_percent() {
    let out = serve::run(&tiny_serve(1));
    assert_eq!(out.points.len(), 3);
    for p in &out.points {
        assert!(p.requests > 0, "load {} generated no traffic", p.load);
        assert_eq!(p.completed + p.dropped, p.requests, "conservation");
        assert!(
            p.little_rel_err <= 0.01,
            "load {}: Little's-law residual {} > 1%",
            p.load,
            p.little_rel_err
        );
        assert!(p.p50_ms <= p.p99_ms && p.p99_ms <= p.p999_ms);
        assert!(p.goodput_rps >= 0.0 && p.tokens_per_s > 0.0);
    }
}

/// Seeded sweeps are bit-identical whatever `--threads` says: per-point
/// arrival seeds are derived from the point index, not from scheduling
/// order, so parallelism affects wall-clock only.
#[test]
fn serve_sweep_is_bit_identical_across_threads() {
    let a = serve::run(&tiny_serve(1));
    let b = serve::run(&tiny_serve(4));
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(b.points.iter()) {
        assert_eq!(x.requests, y.requests);
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.dropped, y.dropped);
        assert_eq!(x.batches, y.batches);
        assert_eq!(x.p99_ms.to_bits(), y.p99_ms.to_bits());
        assert_eq!(x.goodput_rps.to_bits(), y.goodput_rps.to_bits());
        assert_eq!(x.mean_ms.to_bits(), y.mean_ms.to_bits());
        assert_eq!(x.tokens_per_s_mm2.to_bits(), y.tokens_per_s_mm2.to_bits());
    }
}

/// End-to-end serving-objective search (the CI smoke in library form):
/// NSGA-II under `--objective p99` must evaluate the serving workload for
/// every candidate, rank by worst-case p99, keep the artifact's declared
/// objective consistent, and stay bit-reproducible.
#[test]
fn p99_objective_search_scores_every_candidate() {
    let explore = ExploreConfig {
        axes: parse_axes("tiles=36:64,dram").expect("axes parse"),
        budget: 0,
        models: vec![ModelId::OlmoE_1B_7B],
        methods: vec![Method::MozartC],
        scheds: vec![SchedPolicy::Streaming],
        seq_len: 64,
        dram: DramKind::Hbm2,
        iters: 1,
        seed: 11,
        threads: 0,
        eval: EvalOptions::default(),
    };
    let mut cfg = SearchConfig::new(
        explore,
        SearchStrategy::Evolutionary {
            population: 3,
            generations: 2,
            crossover_rate: 0.6,
            mutation_rate: 0.5,
            seed: 9,
        },
    );
    cfg.objective = Objective::P99;
    cfg.serve = Some(ServeEvalSpec {
        duration_s: 0.5,
        ..ServeEvalSpec::paper_default()
    });

    let a = search(&cfg);
    assert!(!a.archive.is_empty(), "p99 search produced an empty frontier");
    for jp in &a.joint {
        let p99 = jp.p99_ms.expect("every candidate carries serve p99");
        let goodput = jp.goodput_rps.expect("every candidate carries goodput");
        assert!(p99.is_finite() && p99 > 0.0);
        assert!(goodput.is_finite() && goodput >= 0.0);
        let objs = jp.objectives_for(Objective::P99);
        assert_eq!(objs[0].to_bits(), p99.to_bits());
    }
    assert_eq!(
        a.hypervolume_ref[0].to_bits(),
        (2.0 * a.joint[0].p99_ms.unwrap()).to_bits(),
        "hypervolume reference must anchor on the serving objective"
    );
    let json = a.to_json().render_pretty();
    assert!(json.contains("\"objective\": \"p99\""));
    assert!(json.contains("\"serve_workload\""));

    // bit-reproducible: identical config => identical frontier and scores
    let b = search(&cfg);
    assert_eq!(a.archive, b.archive);
    for (x, y) in a.joint.iter().zip(b.joint.iter()) {
        assert_eq!(
            x.p99_ms.unwrap().to_bits(),
            y.p99_ms.unwrap().to_bits()
        );
    }
}
