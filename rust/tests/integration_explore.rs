//! Design-space explorer contracts: parallel execution is invisible in the
//! results (same determinism discipline as the sweep executor), the reported
//! Pareto frontier contains no dominated point and excludes every dominated
//! one, and the report/artifact renderers carry the expected structure.

use mozart::config::{DramKind, HwOverride, Method, ModelId, SchedPolicy};
use mozart::coordinator::cache::EvalOptions;
use mozart::coordinator::explore::{explore, Axis, ExploreConfig};
use mozart::metrics::pareto;

/// A tiny 2-axis grid (2 tile counts x 2 DRAM kinds) on the smallest paper
/// model at a reduced workload: 5 variants including the paper anchor.
fn tiny_cfg(threads: usize) -> ExploreConfig {
    ExploreConfig {
        axes: vec![
            Axis {
                name: "tiles".to_string(),
                values: vec![HwOverride::MoeTiles(36), HwOverride::MoeTiles(64)],
            },
            Axis {
                name: "dram".to_string(),
                values: vec![
                    HwOverride::Dram(DramKind::Hbm2),
                    HwOverride::Dram(DramKind::Ssd),
                ],
            },
        ],
        budget: 0,
        models: vec![ModelId::OlmoE_1B_7B],
        methods: vec![Method::MozartC],
        scheds: vec![SchedPolicy::Streaming],
        seq_len: 64,
        dram: DramKind::Hbm2,
        iters: 1,
        seed: 11,
        threads,
        eval: EvalOptions::default(),
    }
}

#[test]
fn tiny_grid_parallel_matches_sequential_bitwise() {
    let seq = explore(&tiny_cfg(1));
    let par = explore(&tiny_cfg(4));
    assert_eq!(seq.points.len(), 5, "paper anchor + 2x2 grid");
    assert_eq!(seq.points.len(), par.points.len());
    for (a, b) in seq.points.iter().zip(par.points.iter()) {
        assert_eq!(a.variant, b.variant);
        assert_eq!(a.model, b.model);
        assert_eq!(a.method, b.method);
        assert_eq!(a.latency_s, b.latency_s, "variant {}", a.variant);
        assert_eq!(a.energy_j, b.energy_j, "variant {}", a.variant);
        assert_eq!(a.area_mm2, b.area_mm2, "variant {}", a.variant);
        assert_eq!(a.c_t, b.c_t, "variant {}", a.variant);
    }
    assert_eq!(seq.frontiers.len(), 1);
    assert_eq!(seq.frontiers[0].members, par.frontiers[0].members);
    assert_eq!(
        seq.frontiers[0].paper_dominators,
        par.frontiers[0].paper_dominators
    );
}

#[test]
fn frontier_is_sound_and_complete() {
    let out = explore(&tiny_cfg(0));
    let objs: Vec<Vec<f64>> = out.points.iter().map(|p| p.objectives()).collect();
    let f = &out.frontiers[0];
    assert!(!f.members.is_empty(), "frontier cannot be empty");
    // soundness: no frontier member is dominated by any evaluated point
    for &m in &f.members {
        assert!(
            pareto::dominators(&objs[m], &objs).is_empty(),
            "frontier point {m} is dominated"
        );
    }
    // completeness: every excluded point is dominated by some member
    for i in 0..out.points.len() {
        if !f.members.contains(&i) {
            assert!(
                f.members
                    .iter()
                    .any(|&m| pareto::dominates(&objs[m], &objs[i])),
                "excluded point {i} is not dominated"
            );
        }
    }
    // the paper-anchor verdict is consistent with the frontier membership
    assert_eq!(
        f.paper_dominators.is_empty(),
        f.members.contains(&f.paper_point)
    );
}

#[test]
fn report_and_artifact_render() {
    let out = explore(&tiny_cfg(0));
    let md = out.render_markdown();
    assert!(md.contains("Design-space axes"));
    assert!(md.contains("Pareto frontier"));
    assert!(md.contains("paper (Table 2)") || md.contains("relative to paper"));
    assert!(md.contains("latency vs energy"));

    let js = out.to_json().render();
    for key in [
        "\"explore\"", "\"axes\"", "\"variants\"", "\"points\"", "\"frontiers\"",
        "\"latency_s\"", "\"energy_j_per_step\"", "\"area_mm2\"", "\"on_frontier\"",
        "\"paper_on_frontier\"", "\"cache\"", "\"hit_rate\"",
    ] {
        assert!(js.contains(key), "artifact missing {key}");
    }
}

#[test]
fn ssd_variants_are_slower_than_their_hbm2_twins() {
    // sanity of the objective wiring: same tile count, worse memory ->
    // strictly worse latency (weight streaming is the bottleneck)
    let out = explore(&tiny_cfg(0));
    let find = |tiles: usize, dram: DramKind| {
        out.points
            .iter()
            .find(|p| {
                let ov = &out.variants[p.variant].overrides;
                ov.contains(&HwOverride::MoeTiles(tiles)) && ov.contains(&HwOverride::Dram(dram))
            })
            .expect("grid cell present")
    };
    for tiles in [36, 64] {
        let hbm = find(tiles, DramKind::Hbm2);
        let ssd = find(tiles, DramKind::Ssd);
        assert!(
            ssd.latency_s > hbm.latency_s,
            "tiles={tiles}: SSD {} !> HBM2 {}",
            ssd.latency_s,
            hbm.latency_s
        );
    }
}
