//! Search-throughput overhaul contracts (ROADMAP item: cell memoization +
//! delta re-timing + surrogate preselection): every reuse layer is
//! bit-transparent end to end — a seeded NSGA-II run with the cache and
//! pooled re-timing on reproduces the uncached run bit for bit, a
//! timing-only explore grid re-times every non-anchor cell, the surrogate
//! at `frac = 1.0` is a no-op, `--min-resilience` simulates each candidate
//! exactly twice (healthy + faulted, never the healthy run twice), and a
//! shared cache file serves a repeat run entirely from memoized cells.

use mozart::config::{DramKind, Method, ModelId, SchedPolicy};
use mozart::coordinator::cache::EvalOptions;
use mozart::coordinator::explore::{explore, parse_axes, ExploreConfig};
use mozart::coordinator::search::{
    search, Constraints, MinResilience, SearchConfig, SearchStrategy,
};

fn explore_cfg(axes: &str) -> ExploreConfig {
    ExploreConfig {
        axes: parse_axes(axes).expect("axes parse"),
        budget: 0,
        models: vec![ModelId::OlmoE_1B_7B],
        methods: vec![Method::MozartC],
        scheds: vec![SchedPolicy::Streaming],
        seq_len: 64,
        dram: DramKind::Hbm2,
        iters: 1,
        seed: 11,
        threads: 1,
        eval: EvalOptions::default(),
    }
}

fn no_reuse() -> EvalOptions {
    EvalOptions {
        cache: false,
        retime: false,
        ..Default::default()
    }
}

fn evolutionary(seed: u64) -> SearchStrategy {
    SearchStrategy::Evolutionary {
        population: 4,
        generations: 3,
        crossover_rate: 0.6,
        mutation_rate: 0.5,
        seed,
    }
}

/// Remove the flat `"cache":{...}` stats object from a rendered artifact.
/// It is the only section allowed to differ between a cached and an
/// uncached run (hit/miss counters are throughput accounting, not results).
fn strip_cache_section(js: &str) -> String {
    let start = js.find("\"cache\":{").expect("artifact has a cache section");
    let end = js[start..].find('}').expect("cache object closes") + start + 1;
    format!("{}{}", &js[..start], &js[end..])
}

/// The PR acceptance criterion: a seeded NSGA-II search over a mixed
/// (topology x timing) genome space with memoization + re-timing on is
/// bit-identical to the same search with every reuse layer off — down to
/// the rendered artifact, modulo the cache-stats section itself.
#[test]
fn cached_search_is_bit_identical_to_uncached() {
    let fast = SearchConfig::new(explore_cfg("tiles=36:64,freq=0.8:1.2"), evolutionary(13));
    let mut slow = fast.clone();
    slow.explore.eval = no_reuse();

    let a = search(&fast);
    let b = search(&slow);

    assert_eq!(a.candidates.len(), b.candidates.len());
    for (x, y) in a.candidates.iter().zip(b.candidates.iter()) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.genome, y.genome);
    }
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(b.cells.iter()) {
        assert_eq!(x.variant, y.variant);
        assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits());
        assert_eq!(x.c_t.to_bits(), y.c_t.to_bits());
    }
    for (x, y) in a.joint.iter().zip(b.joint.iter()) {
        assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits());
        assert_eq!(x.power_w.to_bits(), y.power_w.to_bits());
    }
    assert_eq!(a.archive, b.archive);
    assert_eq!(a.paper_dominators, b.paper_dominators);
    for (x, y) in a.convergence.iter().zip(b.convergence.iter()) {
        assert_eq!(x.hypervolume.to_bits(), y.hypervolume.to_bits());
        assert_eq!(x.archive_size, y.archive_size);
    }
    // the artifacts agree byte for byte outside the cache-stats section
    assert_eq!(
        strip_cache_section(&a.to_json().render()),
        strip_cache_section(&b.to_json().render())
    );
    // the accounting tells the two runs apart
    assert!(a.eval.cache_enabled && a.eval.retime_enabled);
    assert!(a.eval.cache.misses > 0, "cached run never simulated?");
    assert!(!b.eval.cache_enabled && !b.eval.retime_enabled);
    assert_eq!(b.eval.cache.misses + b.eval.cache.hits, 0);
    assert_eq!(b.eval.retimes, 0);
}

/// A frequency-only grid shares the anchor's topology words, so with one
/// worker the explorer builds the topology once and re-times every other
/// cell — and the results still match the no-reuse run bit for bit.
#[test]
fn timing_only_grid_retimes_every_non_anchor_cell() {
    let fast = explore_cfg("freq=0.8:1.2:1.4");
    let mut slow = fast.clone();
    slow.eval = no_reuse();

    let a = explore(&fast);
    let b = explore(&slow);
    assert_eq!(a.points.len(), 4, "paper anchor + 3 frequency points");
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(b.points.iter()) {
        assert_eq!(x.variant, y.variant);
        assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits(), "variant {}", x.variant);
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "variant {}", x.variant);
        assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits(), "variant {}", x.variant);
    }
    assert_eq!(a.frontiers[0].members, b.frontiers[0].members);
    // one build (the first cell), everything else re-timed
    assert_eq!(a.eval.builds, 1, "single worker, single topology");
    assert_eq!(a.eval.retimes, 3);
    assert_eq!(a.eval.cache.hits, 0, "all four cells are distinct");
    assert_eq!(b.eval.retimes, 0);
}

/// `--surrogate-frac 1.0` (the default) is a no-op: no generation logs
/// surrogate stats and the artifact reports the feature disabled. At
/// `0.5` the same seeded random proposal stream is filtered — every cell
/// that IS simulated matches the unfiltered run bit for bit (preselection
/// skips work, it never changes surviving numbers).
#[test]
fn surrogate_frac_one_is_a_no_op_and_half_only_skips_work() {
    let strategy = SearchStrategy::Random { samples: 8, seed: 5 };
    let full = SearchConfig::new(explore_cfg("tiles=36:64,freq=0.8:1.2"), strategy);
    assert_eq!(full.surrogate_frac, 1.0, "preselection defaults to off");
    let a = search(&full);
    assert!(a.convergence.iter().all(|s| s.surrogate.is_none()));
    let js = a.to_json().render();
    assert!(js.contains("\"surrogate\""));
    assert!(js.contains("\"enabled\":false"));

    let mut half = full.clone();
    half.surrogate_frac = 0.5;
    let b = search(&half);
    let stats: Vec<_> = b.convergence.iter().filter_map(|s| s.surrogate.as_ref()).collect();
    assert!(!stats.is_empty(), "frac 0.5 must log surrogate stats");
    assert!(stats.iter().any(|s| s.simulated < s.proposed), "nothing was filtered");
    // same seed -> same proposal stream -> the filtered run evaluates a
    // subset, and every shared candidate has bit-identical objectives
    assert!(b.candidates.len() <= a.candidates.len());
    for (ci, c) in b.candidates.iter().enumerate() {
        let ai = a
            .candidates
            .iter()
            .position(|x| x.label == c.label)
            .expect("filtered run evaluated a candidate the full run did not");
        assert_eq!(
            b.joint[ci].latency_s.to_bits(),
            a.joint[ai].latency_s.to_bits(),
            "candidate `{}`",
            c.label
        );
        assert_eq!(b.joint[ci].energy_j.to_bits(), a.joint[ai].energy_j.to_bits());
        assert_eq!(b.joint[ci].area_mm2.to_bits(), a.joint[ai].area_mm2.to_bits());
    }
}

/// `--min-resilience` costs exactly two simulations per candidate (one
/// healthy, one faulted): the healthy result feeds both the objectives and
/// the retained-throughput ratio, so the cache sees two distinct misses per
/// candidate and zero redundant lookups. A second run sharing the cache
/// file replays entirely from memoized cells — zero simulations — and
/// still reproduces the first run bit for bit.
#[test]
fn resilience_runs_two_cells_per_candidate_and_cache_file_replays() {
    use mozart::comm::FaultScenario;

    let dir = std::env::temp_dir().join(format!("mozart-throughput-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cache_file = dir.join("eval.cache");
    let cache_file = cache_file.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&cache_file);

    let mut ex = explore_cfg("tiles=36:64,dram");
    ex.eval.cache_file = Some(cache_file.clone());
    let cfg = SearchConfig {
        constraints: Constraints {
            min_resilience: Some(MinResilience {
                frac: 0.01,
                scenario: FaultScenario::parse("dram-throttle:0.3", 11).expect("scenario"),
            }),
            ..Constraints::none()
        },
        ..SearchConfig::new(ex, SearchStrategy::Exhaustive)
    };

    let a = search(&cfg);
    let n = a.candidates.len() as u64;
    assert!(n >= 2);
    assert!(a.joint.iter().all(|j| j.resilience.is_some()));
    assert_eq!(
        a.eval.cache.misses,
        2 * n,
        "exactly one healthy + one faulted simulation per candidate"
    );
    assert_eq!(a.eval.cache.hits, 0, "no cell was looked up twice");
    assert_eq!(a.eval.builds + a.eval.retimes, 2 * n);
    assert_eq!(a.eval.cache.entries as u64, 2 * n);

    // run 2: warm-started from the persisted cache — no simulation at all
    let b = search(&cfg);
    assert_eq!(b.eval.cache.loaded as u64, 2 * n);
    assert_eq!(b.eval.cache.hits, 2 * n, "every cell replayed from the file");
    assert_eq!(b.eval.cache.misses, 0);
    assert_eq!(b.eval.builds + b.eval.retimes, 0);
    for (x, y) in a.joint.iter().zip(b.joint.iter()) {
        assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits());
        assert_eq!(
            x.resilience.unwrap().to_bits(),
            y.resilience.unwrap().to_bits()
        );
    }
    assert_eq!(a.archive, b.archive);
    assert_eq!(
        strip_cache_section(&a.to_json().render()),
        strip_cache_section(&b.to_json().render())
    );
    let _ = std::fs::remove_file(&cache_file);
}
