//! Integration tests across the coordinator/pipeline/sim stack: every paper
//! model x method cell runs end to end, the headline orderings hold, and
//! results are deterministic under a fixed seed.

use mozart::config::{DramKind, Method, ModelId};
use mozart::coordinator::sweep::{cell_config, run_cells, Cell};
use mozart::coordinator::{layouts_for, run_experiment};
use mozart::sim::Tag;
use mozart::trace::TraceGen;

fn cell(model: ModelId, method: Method, seq: usize, dram: DramKind) -> Cell {
    Cell {
        model,
        method,
        seq_len: seq,
        dram,
    }
}

/// Reduced-iteration run of one cell (short sequences keep CI fast; the
/// mechanisms under test are seq-independent).
fn quick(model: ModelId, method: Method, seq: usize, dram: DramKind) -> f64 {
    run_experiment(&cell_config(cell(model, method, seq, dram), 1, 7)).latency
}

#[test]
fn every_model_method_cell_runs() {
    for model in ModelId::PAPER_MODELS {
        for method in Method::ALL {
            let lat = quick(model, method, 64, DramKind::Hbm2);
            assert!(lat.is_finite() && lat > 0.0, "{model:?}/{method:?}: {lat}");
        }
    }
}

#[test]
fn table3_orderings_hold_per_model() {
    for model in ModelId::PAPER_MODELS {
        let base = quick(model, Method::Baseline, 128, DramKind::Hbm2);
        let a = quick(model, Method::MozartA, 128, DramKind::Hbm2);
        let b = quick(model, Method::MozartB, 128, DramKind::Hbm2);
        let c = quick(model, Method::MozartC, 128, DramKind::Hbm2);
        assert!(a < base, "{model:?}: A {a} !< base {base}");
        assert!(b < a, "{model:?}: B {b} !< A {a}");
        assert!(c < b * 1.03, "{model:?}: C {c} !<~ B {b}");
        // paper's headline: Mozart-C speedup is >1.5x at seq>=128
        assert!(base / c > 1.3, "{model:?}: speedup only {}", base / c);
    }
}

#[test]
fn latency_grows_with_sequence_length() {
    let l128 = quick(ModelId::Qwen3_30B_A3B, Method::Baseline, 128, DramKind::Hbm2);
    let l256 = quick(ModelId::Qwen3_30B_A3B, Method::Baseline, 256, DramKind::Hbm2);
    let l512 = quick(ModelId::Qwen3_30B_A3B, Method::Baseline, 512, DramKind::Hbm2);
    assert!(l128 < l256 && l256 < l512, "{l128} {l256} {l512}");
    // paper Fig 6(b): latency roughly doubles from 128 to 512, far from 4x
    let ratio = l512 / l128;
    assert!(
        (1.5..3.2).contains(&ratio),
        "512/128 ratio {ratio} outside the paper's regime"
    );
}

#[test]
fn ssd_is_slower_and_compresses_gains() {
    // paper Fig 6(c): SSD slows everything; optimization gains shrink
    let base_h = quick(ModelId::Qwen3_30B_A3B, Method::Baseline, 128, DramKind::Hbm2);
    let c_h = quick(ModelId::Qwen3_30B_A3B, Method::MozartC, 128, DramKind::Hbm2);
    let base_s = quick(ModelId::Qwen3_30B_A3B, Method::Baseline, 128, DramKind::Ssd);
    let c_s = quick(ModelId::Qwen3_30B_A3B, Method::MozartC, 128, DramKind::Ssd);
    assert!(base_s > base_h, "SSD baseline not slower");
    assert!(c_s > c_h, "SSD Mozart-C not slower");
    let speedup_h = base_h / c_h;
    let speedup_s = base_s / c_s;
    assert!(
        speedup_s < speedup_h,
        "SSD speedup {speedup_s} should trail HBM2 {speedup_h}"
    );
}

#[test]
fn deterministic_under_seed() {
    let a = run_experiment(&cell_config(
        cell(ModelId::OlmoE_1B_7B, Method::MozartC, 64, DramKind::Hbm2),
        2,
        13,
    ));
    let b = run_experiment(&cell_config(
        cell(ModelId::OlmoE_1B_7B, Method::MozartC, 64, DramKind::Hbm2),
        2,
        13,
    ));
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.c_t, b.c_t);
    assert_eq!(a.energy.total_j(), b.energy.total_j());
}

#[test]
fn q1_memory_bound_across_models() {
    // weight streaming dominates compute on the critical path for all models
    for model in ModelId::PAPER_MODELS {
        let r = run_experiment(&cell_config(
            cell(model, Method::MozartC, 128, DramKind::Hbm2),
            1,
            7,
        ));
        let stream = r.critical_time(Tag::WeightStream)
            + r.critical_time(Tag::OptimUpdate)
            + r.critical_time(Tag::GradWriteback);
        let compute = r.critical_time(Tag::MoeCompute) + r.critical_time(Tag::AttnCompute);
        assert!(
            stream > compute,
            "{model:?}: memory {stream} !> compute {compute}"
        );
    }
}

#[test]
fn q2_overlap_is_the_biggest_single_lever() {
    // paper Q2: overlap > efficient all-to-all > layout
    for model in ModelId::PAPER_MODELS {
        let base = quick(model, Method::Baseline, 256, DramKind::Hbm2);
        let a = quick(model, Method::MozartA, 256, DramKind::Hbm2);
        let b = quick(model, Method::MozartB, 256, DramKind::Hbm2);
        let c = quick(model, Method::MozartC, 256, DramKind::Hbm2);
        let overlap_gain = base / a;
        let a2a_gain = a / b;
        let layout_gain = b / c;
        assert!(
            overlap_gain > a2a_gain && a2a_gain > layout_gain * 0.99,
            "{model:?}: ordering violated ({overlap_gain:.3} / {a2a_gain:.3} / {layout_gain:.3})"
        );
    }
}

#[test]
fn sweep_grids_run_end_to_end() {
    let cells = vec![
        cell(ModelId::OlmoE_1B_7B, Method::Baseline, 64, DramKind::Hbm2),
        cell(ModelId::OlmoE_1B_7B, Method::MozartC, 64, DramKind::Ssd),
    ];
    let res = run_cells(&cells, 1, 3);
    assert_eq!(res.len(), 2);
    for r in &res {
        assert!(r.result.latency > 0.0);
        assert!(r.result.moe_utilization > 0.0);
    }
}

#[test]
fn energy_tracks_dram_kind() {
    let h = run_experiment(&cell_config(
        cell(ModelId::OlmoE_1B_7B, Method::Baseline, 64, DramKind::Hbm2),
        1,
        7,
    ));
    let s = run_experiment(&cell_config(
        cell(ModelId::OlmoE_1B_7B, Method::Baseline, 64, DramKind::Ssd),
        1,
        7,
    ));
    // SSD: higher per-byte energy AND longer static window
    assert!(s.energy.dram_j > h.energy.dram_j);
    assert!(s.energy.static_j > h.energy.static_j);
}

#[test]
fn mozart_layouts_differ_per_layer() {
    // the per-layer clustering must actually produce distinct layouts
    let cfg = cell_config(
        cell(ModelId::OlmoE_1B_7B, Method::MozartC, 64, DramKind::Hbm2),
        1,
        7,
    );
    let gen = TraceGen::for_model(&cfg.model, cfg.seed);
    let layouts = layouts_for(&cfg, &gen);
    assert_eq!(layouts.len(), cfg.model.n_moe_layers());
    let distinct = layouts
        .windows(2)
        .filter(|w| w[0].expert_to_chiplet != w[1].expert_to_chiplet)
        .count();
    assert!(distinct > 0, "all layers got identical layouts");
}
