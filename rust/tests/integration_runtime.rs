//! Runtime integration tests over the AOT artifacts: the full L1->L2->L3
//! contract. These require `make artifacts`; if the artifacts are missing
//! the tests skip (so `cargo test` works in a fresh checkout), but the
//! Makefile's `test` target always builds them first.

use mozart::runtime::Runtime;
use mozart::train::{run, ArtifactMeta, TrainConfig};

fn artifacts_ready() -> bool {
    ArtifactMeta::load("artifacts").is_ok()
}

#[test]
fn pjrt_platform_is_cpu() {
    assert_eq!(Runtime::cpu().unwrap().platform_name(), "cpu");
}

#[test]
fn init_artifact_produces_documented_state() {
    if !artifacts_ready() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let meta = ArtifactMeta::load("artifacts").unwrap();
    let rt = Runtime::cpu().unwrap();
    let init = rt.load_hlo_text("artifacts/tiny_moe_init.hlo.txt").unwrap();
    let state = init.run(&[]).unwrap();
    assert_eq!(state.len(), meta.n_params);
    // embed is the first param: [vocab, hidden] f32
    let embed_elems = state[0].element_count();
    assert_eq!(embed_elems % meta.vocab, 0);
}

#[test]
fn one_training_step_runs_and_loss_is_sane() {
    if !artifacts_ready() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let meta = ArtifactMeta::load("artifacts").unwrap();
    let summary = run(&TrainConfig {
        artifacts_dir: "artifacts".into(),
        steps: 2,
        log_every: 1,
        seed: 11,
    })
    .unwrap();
    // initial loss near ln(vocab) for a fresh model
    let expect = (meta.vocab as f64).ln();
    assert!(
        (summary.initial_loss() - expect).abs() < 1.5,
        "initial loss {} far from ln(vocab) {expect}",
        summary.initial_loss()
    );
    // router counts populated for every layer
    for layer in &summary.router_counts {
        assert_eq!(layer.len(), meta.n_experts);
        assert!(layer.iter().sum::<f64>() > 0.0);
    }
}

#[test]
fn training_is_deterministic_given_seed() {
    if !artifacts_ready() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let cfg = TrainConfig {
        artifacts_dir: "artifacts".into(),
        steps: 2,
        log_every: 1,
        seed: 21,
    };
    let a = run(&cfg).unwrap();
    let b = run(&cfg).unwrap();
    assert_eq!(a.losses, b.losses);
}

#[test]
fn short_training_reduces_loss() {
    if !artifacts_ready() {
        eprintln!("skipped: run `make artifacts`");
        return;
    }
    let summary = run(&TrainConfig {
        artifacts_dir: "artifacts".into(),
        steps: 30,
        log_every: 29,
        seed: 7,
    })
    .unwrap();
    assert!(
        summary.final_loss() < summary.initial_loss(),
        "loss did not decrease: {} -> {}",
        summary.initial_loss(),
        summary.final_loss()
    );
}
