//! Degrade-sweep contracts: `mozart degrade` emits curves for at least
//! three fault scenarios, the zero-fault path is bit-identical to the
//! healthy simulation, throttle-only curves degrade monotonically, the
//! scenario grammar round-trips, and the artifact schema is stable.

use mozart::comm::FaultScenario;
use mozart::config::{DramKind, Method, ModelId, SchedPolicy};
use mozart::coordinator::cache::EvalOptions;
use mozart::coordinator::degrade::{default_scenarios, run, DegradeConfig};
use mozart::coordinator::run_experiment;
use mozart::coordinator::sweep::{cell_config, Cell};

fn tiny(threads: usize) -> DegradeConfig {
    DegradeConfig {
        models: vec![ModelId::OlmoE_1B_7B],
        methods: vec![Method::MozartC],
        dram: DramKind::Hbm2,
        scenarios: default_scenarios(11),
        steps: 2,
        seq_len: 64,
        iters: 1,
        seed: 11,
        threads,
        budget: 0,
        sched: SchedPolicy::Streaming,
        eval: EvalOptions::default(),
    }
}

#[test]
fn degrade_emits_at_least_three_scenario_curves() {
    let out = run(&tiny(0));
    let mut curves: Vec<&str> = out.points.iter().map(|p| p.scenario.as_str()).collect();
    curves.sort_unstable();
    curves.dedup();
    assert!(
        curves.len() >= 3,
        "need >= 3 fault-scenario curves, got {curves:?}"
    );
    // every curve has the healthy anchor plus every severity step
    let cfg = tiny(0);
    for c in &curves {
        let n = out.points.iter().filter(|p| p.scenario == *c).count();
        assert_eq!(n, cfg.steps + 1, "curve `{c}` incomplete");
    }
}

/// The severity-0 anchor of every curve must be bit-identical to a direct
/// healthy simulation — the degrade sweep's zero-fault regression contract.
#[test]
fn severity_zero_anchor_is_bit_identical_to_healthy() {
    let cfg = tiny(1);
    let out = run(&cfg);
    let healthy = run_experiment(&cell_config(
        Cell {
            model: cfg.models[0],
            method: cfg.methods[0],
            seq_len: cfg.seq_len,
            dram: cfg.dram,
        },
        cfg.iters,
        cfg.seed,
    ))
    .latency;
    let anchors: Vec<_> = out.points.iter().filter(|p| p.severity == 0.0).collect();
    assert_eq!(anchors.len(), cfg.scenarios.len());
    for p in anchors {
        assert_eq!(
            p.latency_s.to_bits(),
            healthy.to_bits(),
            "curve `{}` anchor diverged from the healthy run",
            p.scenario
        );
        assert_eq!(p.retained.to_bits(), 1.0f64.to_bits());
    }
}

/// Throttle-only scenarios (no dead chiplets, so the workload sample is
/// unchanged) must degrade monotonically: retained throughput never rises
/// as severity grows.
#[test]
fn throttle_curves_degrade_monotonically() {
    let mut cfg = tiny(1);
    cfg.steps = 4;
    cfg.scenarios = vec![
        FaultScenario::parse("nop-degrade:0.05", cfg.seed).expect("scenario"),
        FaultScenario::parse("hb-degrade:0.05", cfg.seed).expect("scenario"),
        FaultScenario::parse("dram-throttle:0.05", cfg.seed).expect("scenario"),
        FaultScenario::parse("nop-degrade:0.2,dram-throttle:0.05", cfg.seed)
            .expect("scenario"),
    ];
    let out = run(&cfg);
    for sc in &cfg.scenarios {
        let label = sc.label();
        let mut curve: Vec<(f64, f64)> = out
            .points
            .iter()
            .filter(|p| p.scenario == label)
            .map(|p| (p.severity, p.retained))
            .collect();
        curve.sort_by(|a, b| a.0.total_cmp(&b.0));
        assert_eq!(curve.len(), cfg.steps + 1);
        for w in curve.windows(2) {
            assert!(
                w[1].1 <= w[0].1 + 1e-6,
                "curve `{label}`: retained rose from {} (sev {}) to {} (sev {})",
                w[0].1,
                w[0].0,
                w[1].1,
                w[1].0
            );
        }
        // a 20x throttle on one group's weight streaming is guaranteed to
        // stretch the streaming-dominated critical path strictly; faults on
        // resources with pipeline slack (a single chiplet's compute, the
        // all-to-all trunk) may legitimately be absorbed, so only
        // dram-throttle curves get the strict endpoint check
        if label.contains("dram-throttle") {
            let (_, end) = curve[curve.len() - 1];
            assert!(end < 1.0, "curve `{label}` endpoint retained {end} !< 1");
        }
    }
}

/// The scenario grammar round-trips: parsing a scenario's label reproduces
/// the scenario (same faults, same order), for singletons and compositions.
#[test]
fn scenario_labels_round_trip_through_the_parser() {
    for spec in [
        "dead-chiplet:3",
        "nop-degrade:0.5",
        "hb-degrade:0.25",
        "dram-throttle:0.125",
        "dead-chiplet:2,nop-degrade:0.5",
        "dead-chiplet:1,hb-degrade:0.5,dram-throttle:0.25",
    ] {
        let a = FaultScenario::parse(spec, 42).expect("parse");
        let b = FaultScenario::parse(&a.label(), 42).expect("re-parse");
        assert_eq!(a, b, "label `{}` did not round-trip", a.label());
    }
    // the healthy scenario renders as "healthy" and stays healthy
    assert_eq!(FaultScenario::none().label(), "healthy");
    assert!(FaultScenario::none().is_healthy());
}

/// Same config, two runs (different thread counts): bit-identical curves.
#[test]
fn degrade_sweep_is_reproducible() {
    let a = run(&tiny(1));
    let b = run(&tiny(3));
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(b.points.iter()) {
        assert_eq!(x.scenario, y.scenario);
        assert_eq!(x.severity.to_bits(), y.severity.to_bits());
        assert_eq!(x.latency_s.to_bits(), y.latency_s.to_bits());
        assert_eq!(x.retained.to_bits(), y.retained.to_bits());
    }
}

/// The DEGRADE artifact carries the schema the CI smoke and docs rely on.
#[test]
fn degrade_artifact_schema_is_stable() {
    let out = run(&tiny(0));
    let js = out.to_json().render_pretty();
    for key in [
        "\"artifact\"",
        "\"scenarios\"",
        "\"steps\"",
        "\"seq_len\"",
        "\"iters\"",
        "\"seed\"",
        "\"dram\"",
        "\"dropped_by_budget\"",
        "\"points\"",
        "\"model\"",
        "\"method\"",
        "\"scenario\"",
        "\"severity\"",
        "\"latency_s\"",
        "\"retained\"",
        "\"cache\"",
        "\"hit_rate\"",
    ] {
        assert!(js.contains(key), "artifact missing {key}");
    }
    assert!(js.contains("\"degrade\""));
    let md = out.render_markdown();
    assert!(md.contains("retained throughput vs fault severity"));
    assert!(md.contains("retained vs severity"));
}
