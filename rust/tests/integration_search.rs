//! Guided-search contracts: seeded runs are bit-reproducible, the streaming
//! archive equals the batch Pareto reduction of everything that was
//! evaluated, joint (multi-model) objectives are the worst case across the
//! per-model cells, the exhaustive strategy agrees with the PR-3 explorer,
//! hard `--max-area`/`--max-power` caps keep every frontier point feasible,
//! the method gene searches (hardware × ablation) jointly, and the
//! report/artifact renderers carry the search + feasibility sections.

use mozart::config::{DramKind, HwOverride, KnobId, Method, ModelId, SchedPolicy};
use mozart::coordinator::cache::EvalOptions;
use mozart::coordinator::explore::{explore, parse_axes, ExploreConfig};
use mozart::coordinator::search::{
    search, search_with, Constraints, SearchConfig, SearchStrategy,
};
use mozart::metrics::pareto;

/// A small 2-axis design space on the smallest paper model at a reduced
/// workload (2 tile counts x 2 DRAM kinds; OlmoE's anchor has 56 tiles, so
/// no grid point re-describes the anchor).
fn tiny_explore(threads: usize) -> ExploreConfig {
    ExploreConfig {
        axes: parse_axes("tiles=36:64,dram").expect("axes parse"),
        budget: 0,
        models: vec![ModelId::OlmoE_1B_7B],
        methods: vec![Method::MozartC],
        scheds: vec![SchedPolicy::Streaming],
        seq_len: 64,
        dram: DramKind::Hbm2,
        iters: 1,
        seed: 11,
        threads,
        eval: EvalOptions::default(),
    }
}

fn evolutionary(seed: u64) -> SearchStrategy {
    SearchStrategy::Evolutionary {
        population: 3,
        generations: 3,
        crossover_rate: 0.6,
        mutation_rate: 0.5,
        seed,
    }
}

#[test]
fn evolutionary_search_is_bit_reproducible() {
    let cfg = SearchConfig::new(tiny_explore(0), evolutionary(13));
    let a = search(&cfg);
    let b = search(&cfg);
    assert_eq!(a.candidates.len(), b.candidates.len());
    for (x, y) in a.candidates.iter().zip(b.candidates.iter()) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.genome, y.genome);
    }
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(b.cells.iter()) {
        assert_eq!(x.variant, y.variant);
        assert_eq!(x.latency_s, y.latency_s, "candidate {}", x.variant);
        assert_eq!(x.energy_j, y.energy_j, "candidate {}", x.variant);
        assert_eq!(x.area_mm2, y.area_mm2, "candidate {}", x.variant);
        assert_eq!(x.c_t, y.c_t, "candidate {}", x.variant);
    }
    assert_eq!(a.archive, b.archive);
    assert_eq!(a.paper_dominators, b.paper_dominators);
    assert_eq!(a.convergence.len(), b.convergence.len());
    for (x, y) in a.convergence.iter().zip(b.convergence.iter()) {
        assert_eq!(x.evaluations, y.evaluations);
        assert_eq!(x.archive_size, y.archive_size);
        assert_eq!(x.hypervolume, y.hypervolume, "gen {}", x.generation);
    }
    // a different strategy seed explores a (generally) different trajectory
    // but still re-evaluates nothing twice
    let c = search(&SearchConfig::new(tiny_explore(0), evolutionary(14)));
    let mut genomes: Vec<_> = c.candidates.iter().filter_map(|x| x.genome.clone()).collect();
    genomes.sort();
    let unique = genomes.len();
    genomes.dedup();
    assert_eq!(genomes.len(), unique, "a genome was evaluated twice");
}

#[test]
fn search_parallel_matches_sequential_bitwise() {
    let seq = search(&SearchConfig::new(tiny_explore(1), evolutionary(13)));
    let par = search(&SearchConfig::new(tiny_explore(4), evolutionary(13)));
    assert_eq!(seq.cells.len(), par.cells.len());
    for (x, y) in seq.cells.iter().zip(par.cells.iter()) {
        assert_eq!(x.variant, y.variant);
        assert_eq!(x.latency_s, y.latency_s);
        assert_eq!(x.energy_j, y.energy_j);
        assert_eq!(x.area_mm2, y.area_mm2);
    }
    assert_eq!(seq.archive, par.archive);
}

#[test]
fn archive_matches_batch_pareto_reduction() {
    let out = search(&SearchConfig::new(tiny_explore(0), evolutionary(13)));
    let objs: Vec<Vec<f64>> = out.joint.iter().map(|j| j.objectives()).collect();
    assert_eq!(out.archive, pareto::pareto_frontier(&objs));
    // archive soundness on the evaluated set
    for &m in &out.archive {
        assert!(
            pareto::dominators(&objs[m], &objs).is_empty(),
            "archive member {m} is dominated"
        );
    }
    // the paper-anchor verdict is consistent with archive membership
    assert_eq!(out.paper_dominators.is_empty(), out.archive.contains(&0));
}

#[test]
fn exhaustive_strategy_agrees_with_the_explorer() {
    let ex = tiny_explore(0);
    let grid = explore(&ex);
    let out = search(&SearchConfig::new(ex, SearchStrategy::Exhaustive));
    // same candidate set in the same order (anchor first, then grid order),
    // evaluated through the same cell path -> bit-identical objectives
    assert_eq!(out.candidates.len(), grid.variants.len());
    assert_eq!(out.cells.len(), grid.points.len());
    for (c, v) in out.candidates.iter().zip(grid.variants.iter()) {
        assert_eq!(c.label, v.label);
    }
    for (a, b) in out.cells.iter().zip(grid.points.iter()) {
        assert_eq!(a.variant, b.variant);
        assert_eq!(a.latency_s, b.latency_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.area_mm2, b.area_mm2);
    }
    // with a single model the joint frontier degenerates to the explorer's
    // per-(model, method) frontier (point indices -> variant indices)
    let mut explorer_members: Vec<usize> = grid.frontiers[0]
        .members
        .iter()
        .map(|&i| grid.points[i].variant)
        .collect();
    explorer_members.sort_unstable();
    assert_eq!(out.archive, explorer_members);
}

#[test]
fn joint_objectives_are_worst_case_across_models() {
    // TinyMoE is cheap and its paper platform (36 tiles) differs from
    // OlmoE's (56), so the same override set produces different per-model
    // hardware — exactly the case joint frontiers exist for.
    let mut ex = tiny_explore(0);
    ex.models = vec![ModelId::OlmoE_1B_7B, ModelId::TinyMoE];
    let out = search(&SearchConfig::new(
        ex,
        SearchStrategy::Random { samples: 4, seed: 5 },
    ));
    let per = 2; // models x methods
    for j in &out.joint {
        assert_eq!(j.cells.len(), per, "candidate {}", j.candidate);
        let max = |f: fn(&mozart::coordinator::explore::ExplorePoint) -> f64| {
            j.cells
                .iter()
                .map(|&c| f(&out.cells[c]))
                .fold(f64::NEG_INFINITY, f64::max)
        };
        assert_eq!(j.latency_s, max(|p| p.latency_s), "candidate {}", j.candidate);
        assert_eq!(j.energy_j, max(|p| p.energy_j), "candidate {}", j.candidate);
        assert_eq!(j.area_mm2, max(|p| p.area_mm2), "candidate {}", j.candidate);
        for &c in &j.cells {
            assert_eq!(out.cells[c].variant, j.candidate);
        }
    }
    // every cell of every candidate was evaluated for both models
    for j in &out.joint {
        let models: Vec<ModelId> = j.cells.iter().map(|&c| out.cells[c].model).collect();
        assert!(models.contains(&ModelId::OlmoE_1B_7B));
        assert!(models.contains(&ModelId::TinyMoE));
    }
}

#[test]
fn knob_axes_search_end_to_end() {
    let mut ex = tiny_explore(0);
    ex.axes = parse_axes("tiles=36:64,knob=mxu_util:0.4:0.8").expect("axes parse");
    assert_eq!(ex.axes[1].values.len(), 5);
    assert_eq!(
        ex.axes[1].values[0],
        HwOverride::Knob(KnobId::MxuUtil, 0.4)
    );
    let out = search(&SearchConfig::new(
        ex,
        SearchStrategy::Random { samples: 4, seed: 3 },
    ));
    assert!(out.candidates.len() >= 2, "random proposals all collapsed");
    for c in out.candidates.iter().skip(1) {
        assert!(c.label.contains("mxu_util="), "label `{}`", c.label);
    }
    for j in &out.joint {
        assert!(j.latency_s.is_finite() && j.latency_s > 0.0);
        assert!(j.energy_j.is_finite() && j.energy_j > 0.0);
        assert!(j.area_mm2.is_finite() && j.area_mm2 > 0.0);
    }
}

#[test]
fn report_artifact_and_progress_render() {
    let mut gens = 0usize;
    let out = search_with(
        &SearchConfig::new(tiny_explore(0), evolutionary(13)),
        |s| {
            gens += 1;
            assert_eq!(s.generation, gens);
            assert!(s.evaluations >= 1);
            assert!(s.hypervolume.is_finite() && s.hypervolume >= 0.0);
        },
    );
    assert_eq!(gens, 3, "one progress callback per generation");
    assert_eq!(out.convergence.len(), 3);
    // evaluations are cumulative and never shrink
    for w in out.convergence.windows(2) {
        assert!(w[1].evaluations >= w[0].evaluations);
    }

    let md = out.render_markdown();
    assert!(md.contains("Design-space axes"));
    assert!(md.contains("Joint Pareto frontier"));
    assert!(md.contains("strategy evolutionary"));
    assert!(md.contains("convergence"));
    assert!(md.contains("paper (Table 2)") || md.contains("relative to paper"));

    let js = out.to_json().render();
    for key in [
        "\"explore\"", "\"design_space_search\"", "\"candidates\"", "\"points\"",
        "\"joint\"", "\"frontier\"", "\"search\"", "\"strategy\"", "\"evolutionary\"",
        "\"convergence\"", "\"hypervolume\"", "\"objective_mode\"",
        "\"worst_case_across_models\"", "\"on_frontier\"", "\"paper_on_frontier\"",
        "\"population\"", "\"mutation_rate\"", "\"crossover_rate\"",
        "\"feasibility\"", "\"constrained\"", "\"max_area_mm2\"", "\"max_power_w\"",
        "\"min_resilience\"", "\"resilience_scenario\"", "\"retained\"",
        "\"resilience\"", "\"anchor_feasible\"", "\"method_gene\"",
        "\"mean_power_w\"", "\"power_w\"",
        "\"cache\"", "\"hit_rate\"", "\"surrogate\"", "\"surrogate_frac\"",
    ] {
        assert!(js.contains(key), "artifact missing {key}");
    }
    // unconstrained run: every candidate is feasible and the feasibility
    // section says so
    assert_eq!(out.n_feasible(), out.candidates.len());
    assert!(js.contains("\"constrained\":false"));
}

/// Self-calibrating hard-cap test: run the exhaustive search unconstrained,
/// pick a cap that genuinely splits the evaluated candidates, rerun with the
/// cap, and require every frontier point to satisfy it.
#[test]
fn constrained_search_frontier_respects_hard_caps() {
    let base = search(&SearchConfig::new(tiny_explore(0), SearchStrategy::Exhaustive));
    let mut areas: Vec<f64> = base.joint.iter().map(|j| j.area_mm2).collect();
    areas.sort_by(f64::total_cmp);
    let cap = areas[areas.len() / 2]; // median area: both sides non-empty

    let out = search(&SearchConfig {
        constraints: Constraints {
            max_area_mm2: Some(cap),
            ..Constraints::none()
        },
        ..SearchConfig::new(tiny_explore(0), SearchStrategy::Exhaustive)
    });
    assert!(
        out.joint.iter().any(|j| j.area_mm2 > cap),
        "cap did not exclude anything"
    );
    assert!(!out.archive.is_empty(), "median cap leaves feasible points");
    for &ci in &out.archive {
        assert!(
            out.joint[ci].area_mm2 <= cap,
            "frontier point {ci} violates --max-area ({} > {cap})",
            out.joint[ci].area_mm2
        );
        assert!(out.is_feasible(ci));
    }
    // the archive equals the batch Pareto reduction of the FEASIBLE subset
    let feasible: Vec<usize> =
        (0..out.candidates.len()).filter(|&c| out.is_feasible(c)).collect();
    let fobjs: Vec<Vec<f64>> = feasible.iter().map(|&c| out.joint[c].objectives()).collect();
    let mut expect: Vec<usize> = pareto::pareto_frontier(&fobjs)
        .into_iter()
        .map(|k| feasible[k])
        .collect();
    expect.sort_unstable();
    assert_eq!(out.archive, expect);
    assert_eq!(out.n_feasible(), feasible.len());

    // the same contract holds for a power cap under the NSGA-II strategy
    let mut powers: Vec<f64> = base.joint.iter().map(|j| j.power_w).collect();
    powers.sort_by(f64::total_cmp);
    let pcap = powers[powers.len() / 2];
    let out = search(&SearchConfig {
        constraints: Constraints {
            max_power_w: Some(pcap),
            ..Constraints::none()
        },
        ..SearchConfig::new(tiny_explore(0), evolutionary(13))
    });
    for &ci in &out.archive {
        assert!(
            out.joint[ci].power_w <= pcap,
            "frontier point {ci} violates --max-power"
        );
    }
    // feasibility counts are monotone along the convergence curve and
    // bounded by the evaluations
    for s in &out.convergence {
        assert!(s.feasible <= s.evaluations);
    }
    for w in out.convergence.windows(2) {
        assert!(w[1].feasible >= w[0].feasible);
    }
}

/// An impossible budget: everything infeasible, the frontier empty, and the
/// artifact/report still render (the CI NSGA-II smoke exercises the same
/// path end to end).
#[test]
fn impossible_constraints_yield_an_empty_frontier() {
    let out = search(&SearchConfig {
        constraints: Constraints {
            max_area_mm2: Some(1.0), // 1 mm^2: nothing fits
            ..Constraints::none()
        },
        ..SearchConfig::new(tiny_explore(0), evolutionary(13))
    });
    assert!(out.archive.is_empty());
    assert_eq!(out.n_feasible(), 0);
    assert!(!out.is_feasible(0));
    let md = out.render_markdown();
    assert!(md.contains("no feasible candidate"));
    assert!(md.contains("VIOLATES the constraints"));
    let js = out.to_json().render();
    assert!(js.contains("\"anchor_feasible\":false"));
    assert!(js.contains("\"feasible\":0"));
}

/// The PR-6 acceptance criterion: an NSGA-II run with a `--min-resilience`
/// floor rejects at least one platform the unconstrained search accepts,
/// and no rejected platform reaches the frontier archive.
#[test]
fn resilience_floor_rejects_fragile_platforms() {
    use mozart::comm::FaultScenario;
    use mozart::coordinator::search::MinResilience;

    let scenario =
        FaultScenario::parse("dead-chiplet:4,dram-throttle:0.2", 11).expect("scenario");

    // unconstrained exhaustive baseline: every evaluated platform accepted,
    // no resilience evaluation runs
    let base = search(&SearchConfig::new(tiny_explore(0), SearchStrategy::Exhaustive));
    assert_eq!(base.n_feasible(), base.candidates.len());
    assert!(base.joint.iter().all(|j| j.resilience.is_none()));

    // probe pass with a permissive floor: measures every platform's
    // retained throughput under the scenario
    let probe = search(&SearchConfig {
        constraints: Constraints {
            min_resilience: Some(MinResilience {
                frac: 0.01,
                scenario: scenario.clone(),
            }),
            ..Constraints::none()
        },
        ..SearchConfig::new(tiny_explore(0), SearchStrategy::Exhaustive)
    });
    let rvals: Vec<f64> = probe
        .joint
        .iter()
        .map(|j| j.resilience.expect("floor set -> resilience evaluated"))
        .collect();
    for &r in &rvals {
        assert!(r.is_finite() && r > 0.0 && r <= 1.0 + 1e-9, "retained {r}");
    }
    let min = rvals.iter().copied().fold(f64::INFINITY, f64::min);
    let max = rvals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    assert!(
        min < max,
        "scenario does not discriminate platforms (retained == {min} everywhere)"
    );

    // NSGA-II with the floor at the best observed resilience: every
    // platform weaker than the best becomes infeasible
    let floor = max.min(1.0);
    let out = search(&SearchConfig {
        constraints: Constraints {
            min_resilience: Some(MinResilience {
                frac: floor,
                scenario,
            }),
            ..Constraints::none()
        },
        ..SearchConfig::new(tiny_explore(0), evolutionary(13))
    });
    let rejected: Vec<usize> = (0..out.candidates.len())
        .filter(|&c| !out.is_feasible(c))
        .collect();
    assert!(
        !rejected.is_empty(),
        "the resilience floor rejected no platform"
    );
    for &ci in &rejected {
        // the unconstrained exhaustive run covered the full grid, so every
        // rejected platform appears there — and was accepted
        let label = &out.candidates[ci].label;
        let bi = base
            .candidates
            .iter()
            .position(|c| &c.label == label)
            .expect("exhaustive base covers every platform");
        assert!(base.is_feasible(bi), "`{label}` accepted unconstrained");
        assert!(!out.archive.contains(&ci), "rejected `{label}` on frontier");
    }
    // frontier members all satisfy the floor
    for &ci in &out.archive {
        let r = out.joint[ci].resilience.expect("evaluated under the floor");
        assert!(r >= floor - 1e-12, "frontier member below the floor: {r}");
    }
    // the artifact records the floor and its scenario
    let js = out.to_json().render();
    assert!(js.contains("\"min_resilience\":"));
    assert!(js.contains("\"resilience_scenario\":"));
    assert!(js.contains("dead-chiplet:4"));
    assert!(js.contains("\"resilience\":"));
}

/// The method gene: every candidate carries exactly one ablation, the
/// exhaustive gene grid is (hardware x methods), and the anchor is the
/// paper platform running its deployed method (Mozart-C).
#[test]
fn method_gene_searches_hardware_and_ablation_jointly() {
    let mut ex = tiny_explore(0);
    ex.methods = Method::ALL.to_vec();
    let out = search(&SearchConfig {
        method_gene: true,
        ..SearchConfig::new(ex, SearchStrategy::Exhaustive)
    });
    // anchor: paper hardware + Mozart-C only
    assert_eq!(out.candidates[0].method, Some(Method::MozartC));
    assert!(out.candidates[0].label.contains("method=Mozart-C"));
    assert_eq!(out.joint[0].cells.len(), 1, "gene-mode anchor runs one method");
    // 2x2 hardware grid x 4 methods (no combo equals OlmoE's 56-tile
    // anchor) + the anchor itself
    assert_eq!(out.candidates.len(), 17);
    // every candidate's cells carry exactly its method gene
    for j in &out.joint {
        let method = out.candidates[j.candidate].method.expect("gene set");
        assert_eq!(j.cells.len(), 1, "one model x one method per candidate");
        for &c in &j.cells {
            assert_eq!(out.cells[c].method, method);
            assert_eq!(out.cells[c].variant, j.candidate);
        }
    }
    // each (hardware label, method) pair appears exactly once
    let mut labels: Vec<&str> = out.candidates.iter().map(|c| c.label.as_str()).collect();
    labels.sort_unstable();
    let unique = labels.len();
    labels.dedup();
    assert_eq!(labels.len(), unique, "duplicate (hardware, method) candidate");
    // the gene run is reproducible too
    let mut ex = tiny_explore(0);
    ex.methods = Method::ALL.to_vec();
    let again = search(&SearchConfig {
        method_gene: true,
        ..SearchConfig::new(ex, SearchStrategy::Exhaustive)
    });
    assert_eq!(out.archive, again.archive);
    // artifact carries the gene: every candidate names a method
    let js = out.to_json().render();
    assert!(js.contains("\"method_gene\":true"));
    assert!(js.contains("\"method\":\"Baseline\""));
}

/// The gene also works under the NSGA-II strategy with constraints: the
/// frontier answers "which ablation on which platform, within budget".
#[test]
fn method_gene_under_constrained_nsga2() {
    let mut ex = tiny_explore(0);
    ex.methods = vec![Method::Baseline, Method::MozartC];
    // self-calibrate an area cap off the unconstrained gene grid
    let base = search(&SearchConfig {
        method_gene: true,
        ..SearchConfig::new(ex.clone(), SearchStrategy::Exhaustive)
    });
    let mut areas: Vec<f64> = base.joint.iter().map(|j| j.area_mm2).collect();
    areas.sort_by(f64::total_cmp);
    let cap = areas[areas.len() / 2];

    let out = search(&SearchConfig {
        constraints: Constraints {
            max_area_mm2: Some(cap),
            ..Constraints::none()
        },
        method_gene: true,
        ..SearchConfig::new(ex, evolutionary(13))
    });
    for &ci in &out.archive {
        assert!(out.joint[ci].area_mm2 <= cap);
        assert!(out.candidates[ci].method.is_some());
    }
    // genomes cover the widened space: hw genes + 1 method gene
    for c in out.candidates.iter().skip(1) {
        let g = c.genome.as_ref().expect("searched candidates carry genomes");
        assert_eq!(g.len(), 3, "2 hw axes + 1 method gene");
        assert!(g[2] < 2, "method gene out of range");
    }
}
