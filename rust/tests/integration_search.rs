//! Guided-search contracts: seeded runs are bit-reproducible, the streaming
//! archive equals the batch Pareto reduction of everything that was
//! evaluated, joint (multi-model) objectives are the worst case across the
//! per-model cells, the exhaustive strategy agrees with the PR-3 explorer,
//! and the report/artifact renderers carry the search section.

use mozart::config::{DramKind, HwOverride, KnobId, Method, ModelId};
use mozart::coordinator::explore::{explore, parse_axes, ExploreConfig};
use mozart::coordinator::search::{search, search_with, SearchConfig, SearchStrategy};
use mozart::metrics::pareto;

/// A small 2-axis design space on the smallest paper model at a reduced
/// workload (2 tile counts x 2 DRAM kinds; OlmoE's anchor has 56 tiles, so
/// no grid point re-describes the anchor).
fn tiny_explore(threads: usize) -> ExploreConfig {
    ExploreConfig {
        axes: parse_axes("tiles=36:64,dram").expect("axes parse"),
        budget: 0,
        models: vec![ModelId::OlmoE_1B_7B],
        methods: vec![Method::MozartC],
        seq_len: 64,
        dram: DramKind::Hbm2,
        iters: 1,
        seed: 11,
        threads,
    }
}

fn evolutionary(seed: u64) -> SearchStrategy {
    SearchStrategy::Evolutionary {
        population: 3,
        generations: 3,
        mutation_rate: 0.5,
        seed,
    }
}

#[test]
fn evolutionary_search_is_bit_reproducible() {
    let cfg = SearchConfig {
        explore: tiny_explore(0),
        strategy: evolutionary(13),
    };
    let a = search(&cfg);
    let b = search(&cfg);
    assert_eq!(a.candidates.len(), b.candidates.len());
    for (x, y) in a.candidates.iter().zip(b.candidates.iter()) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.genome, y.genome);
    }
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(b.cells.iter()) {
        assert_eq!(x.variant, y.variant);
        assert_eq!(x.latency_s, y.latency_s, "candidate {}", x.variant);
        assert_eq!(x.energy_j, y.energy_j, "candidate {}", x.variant);
        assert_eq!(x.area_mm2, y.area_mm2, "candidate {}", x.variant);
        assert_eq!(x.c_t, y.c_t, "candidate {}", x.variant);
    }
    assert_eq!(a.archive, b.archive);
    assert_eq!(a.paper_dominators, b.paper_dominators);
    assert_eq!(a.convergence.len(), b.convergence.len());
    for (x, y) in a.convergence.iter().zip(b.convergence.iter()) {
        assert_eq!(x.evaluations, y.evaluations);
        assert_eq!(x.archive_size, y.archive_size);
        assert_eq!(x.hypervolume, y.hypervolume, "gen {}", x.generation);
    }
    // a different strategy seed explores a (generally) different trajectory
    // but still re-evaluates nothing twice
    let c = search(&SearchConfig {
        explore: tiny_explore(0),
        strategy: evolutionary(14),
    });
    let mut genomes: Vec<_> = c.candidates.iter().filter_map(|x| x.genome.clone()).collect();
    genomes.sort();
    let unique = genomes.len();
    genomes.dedup();
    assert_eq!(genomes.len(), unique, "a genome was evaluated twice");
}

#[test]
fn search_parallel_matches_sequential_bitwise() {
    let seq = search(&SearchConfig {
        explore: tiny_explore(1),
        strategy: evolutionary(13),
    });
    let par = search(&SearchConfig {
        explore: tiny_explore(4),
        strategy: evolutionary(13),
    });
    assert_eq!(seq.cells.len(), par.cells.len());
    for (x, y) in seq.cells.iter().zip(par.cells.iter()) {
        assert_eq!(x.variant, y.variant);
        assert_eq!(x.latency_s, y.latency_s);
        assert_eq!(x.energy_j, y.energy_j);
        assert_eq!(x.area_mm2, y.area_mm2);
    }
    assert_eq!(seq.archive, par.archive);
}

#[test]
fn archive_matches_batch_pareto_reduction() {
    let out = search(&SearchConfig {
        explore: tiny_explore(0),
        strategy: evolutionary(13),
    });
    let objs: Vec<Vec<f64>> = out.joint.iter().map(|j| j.objectives()).collect();
    assert_eq!(out.archive, pareto::pareto_frontier(&objs));
    // archive soundness on the evaluated set
    for &m in &out.archive {
        assert!(
            pareto::dominators(&objs[m], &objs).is_empty(),
            "archive member {m} is dominated"
        );
    }
    // the paper-anchor verdict is consistent with archive membership
    assert_eq!(out.paper_dominators.is_empty(), out.archive.contains(&0));
}

#[test]
fn exhaustive_strategy_agrees_with_the_explorer() {
    let ex = tiny_explore(0);
    let grid = explore(&ex);
    let out = search(&SearchConfig {
        explore: ex,
        strategy: SearchStrategy::Exhaustive,
    });
    // same candidate set in the same order (anchor first, then grid order),
    // evaluated through the same cell path -> bit-identical objectives
    assert_eq!(out.candidates.len(), grid.variants.len());
    assert_eq!(out.cells.len(), grid.points.len());
    for (c, v) in out.candidates.iter().zip(grid.variants.iter()) {
        assert_eq!(c.label, v.label);
    }
    for (a, b) in out.cells.iter().zip(grid.points.iter()) {
        assert_eq!(a.variant, b.variant);
        assert_eq!(a.latency_s, b.latency_s);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.area_mm2, b.area_mm2);
    }
    // with a single model the joint frontier degenerates to the explorer's
    // per-(model, method) frontier (point indices -> variant indices)
    let mut explorer_members: Vec<usize> = grid.frontiers[0]
        .members
        .iter()
        .map(|&i| grid.points[i].variant)
        .collect();
    explorer_members.sort_unstable();
    assert_eq!(out.archive, explorer_members);
}

#[test]
fn joint_objectives_are_worst_case_across_models() {
    // TinyMoE is cheap and its paper platform (36 tiles) differs from
    // OlmoE's (56), so the same override set produces different per-model
    // hardware — exactly the case joint frontiers exist for.
    let mut ex = tiny_explore(0);
    ex.models = vec![ModelId::OlmoE_1B_7B, ModelId::TinyMoE];
    let out = search(&SearchConfig {
        explore: ex,
        strategy: SearchStrategy::Random { samples: 4, seed: 5 },
    });
    let per = 2; // models x methods
    for j in &out.joint {
        assert_eq!(j.cells.len(), per, "candidate {}", j.candidate);
        let max = |f: fn(&mozart::coordinator::explore::ExplorePoint) -> f64| {
            j.cells
                .iter()
                .map(|&c| f(&out.cells[c]))
                .fold(f64::NEG_INFINITY, f64::max)
        };
        assert_eq!(j.latency_s, max(|p| p.latency_s), "candidate {}", j.candidate);
        assert_eq!(j.energy_j, max(|p| p.energy_j), "candidate {}", j.candidate);
        assert_eq!(j.area_mm2, max(|p| p.area_mm2), "candidate {}", j.candidate);
        for &c in &j.cells {
            assert_eq!(out.cells[c].variant, j.candidate);
        }
    }
    // every cell of every candidate was evaluated for both models
    for j in &out.joint {
        let models: Vec<ModelId> = j.cells.iter().map(|&c| out.cells[c].model).collect();
        assert!(models.contains(&ModelId::OlmoE_1B_7B));
        assert!(models.contains(&ModelId::TinyMoE));
    }
}

#[test]
fn knob_axes_search_end_to_end() {
    let mut ex = tiny_explore(0);
    ex.axes = parse_axes("tiles=36:64,knob=mxu_util:0.4:0.8").expect("axes parse");
    assert_eq!(ex.axes[1].values.len(), 5);
    assert_eq!(
        ex.axes[1].values[0],
        HwOverride::Knob(KnobId::MxuUtil, 0.4)
    );
    let out = search(&SearchConfig {
        explore: ex,
        strategy: SearchStrategy::Random { samples: 4, seed: 3 },
    });
    assert!(out.candidates.len() >= 2, "random proposals all collapsed");
    for c in out.candidates.iter().skip(1) {
        assert!(c.label.contains("mxu_util="), "label `{}`", c.label);
    }
    for j in &out.joint {
        assert!(j.latency_s.is_finite() && j.latency_s > 0.0);
        assert!(j.energy_j.is_finite() && j.energy_j > 0.0);
        assert!(j.area_mm2.is_finite() && j.area_mm2 > 0.0);
    }
}

#[test]
fn report_artifact_and_progress_render() {
    let mut gens = 0usize;
    let out = search_with(
        &SearchConfig {
            explore: tiny_explore(0),
            strategy: evolutionary(13),
        },
        |s| {
            gens += 1;
            assert_eq!(s.generation, gens);
            assert!(s.evaluations >= 1);
            assert!(s.hypervolume.is_finite() && s.hypervolume >= 0.0);
        },
    );
    assert_eq!(gens, 3, "one progress callback per generation");
    assert_eq!(out.convergence.len(), 3);
    // evaluations are cumulative and never shrink
    for w in out.convergence.windows(2) {
        assert!(w[1].evaluations >= w[0].evaluations);
    }

    let md = out.render_markdown();
    assert!(md.contains("Design-space axes"));
    assert!(md.contains("Joint Pareto frontier"));
    assert!(md.contains("strategy evolutionary"));
    assert!(md.contains("convergence"));
    assert!(md.contains("paper (Table 2)") || md.contains("relative to paper"));

    let js = out.to_json().render();
    for key in [
        "\"explore\"", "\"design_space_search\"", "\"candidates\"", "\"points\"",
        "\"joint\"", "\"frontier\"", "\"search\"", "\"strategy\"", "\"evolutionary\"",
        "\"convergence\"", "\"hypervolume\"", "\"objective_mode\"",
        "\"worst_case_across_models\"", "\"on_frontier\"", "\"paper_on_frontier\"",
        "\"population\"", "\"mutation_rate\"",
    ] {
        assert!(js.contains(key), "artifact missing {key}");
    }
}
