//! Determinism contract of the parallel sweep executor: fanning the grid
//! out across threads must be invisible in the results. Every cell derives
//! its randomness from its own config seed, so parallel and sequential
//! sweeps are bit-identical per cell.

use mozart::coordinator::sweep::{
    run_cells_seq, run_cells_with, table3_cells, SweepOptions,
};

#[test]
fn table3_parallel_matches_sequential_bitwise() {
    let cells = table3_cells();
    let seq = run_cells_seq(&cells, 1, 7);
    let par = run_cells_with(&cells, 1, 7, SweepOptions::default());

    assert_eq!(seq.len(), par.len());
    for (s, p) in seq.iter().zip(par.iter()) {
        // same cell in the same output slot
        assert_eq!(s.cell.model, p.cell.model);
        assert_eq!(s.cell.method, p.cell.method);
        assert_eq!(s.cell.seq_len, p.cell.seq_len);
        assert_eq!(s.cell.dram, p.cell.dram);
        let label = format!("{:?}/{:?}", s.cell.model, s.cell.method);
        // bit-identical aggregates (no tolerance)
        assert_eq!(s.result.latency, p.result.latency, "{label}: latency");
        assert_eq!(
            s.result.latency_std, p.result.latency_std,
            "{label}: latency_std"
        );
        assert_eq!(s.result.c_t, p.result.c_t, "{label}: c_t");
        assert_eq!(s.result.tag_busy, p.result.tag_busy, "{label}: tag_busy");
        assert_eq!(s.result.critical, p.result.critical, "{label}: critical");
        assert_eq!(
            s.result.energy.total_j(),
            p.result.energy.total_j(),
            "{label}: energy"
        );
        assert_eq!(
            s.result.moe_utilization, p.result.moe_utilization,
            "{label}: utilization"
        );
    }
}

#[test]
fn oversubscribed_pool_is_still_deterministic() {
    // more workers than cells + a rerun: claim order varies, results don't
    let cells: Vec<_> = table3_cells().into_iter().take(4).collect();
    let a = run_cells_with(&cells, 1, 13, SweepOptions { threads: 16 });
    let b = run_cells_with(&cells, 1, 13, SweepOptions { threads: 2 });
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.result.latency, y.result.latency);
        assert_eq!(x.result.c_t, y.result.c_t);
    }
}
