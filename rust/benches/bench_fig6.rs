//! Bench for paper Figure 6(b) sequence-length study and 6(c) DRAM study.
use mozart::report::{fig6b, fig6c, ReportOpts};
use mozart::testkit::bench;

fn main() {
    let opts = ReportOpts { iters: 2, seed: 7 };
    let mut b = String::new();
    let mut c = String::new();
    bench("fig6b: seq sweep 128/256/512 x 4 methods", 2, || {
        b = fig6b(opts);
    });
    bench("fig6c: HBM2 vs SSD x 4 methods", 2, || {
        c = fig6c(opts);
    });
    println!("\n{b}\n{c}");
}
