//! Bench for appendix Figures 14-16: GPU power/memory dynamism under 4-way
//! expert parallelism.
use mozart::report::{fig14_16, ReportOpts};
use mozart::testkit::bench;

fn main() {
    let opts = ReportOpts { iters: 1, seed: 7 };
    let mut rendered = String::new();
    bench("fig14-16: 40s EP monitor simulation", 5, || {
        rendered = fig14_16(opts);
    });
    println!("\n{rendered}");
}
