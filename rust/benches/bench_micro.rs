//! Micro-benches over the L3 hot paths: trace sampling, prior computation,
//! clustering, allocation, plan building, and the discrete-event engine.
//! These are the perf-regression guards for the sweep hot path (see
//! rust/DESIGN.md §"The sweep/simulation hot path").
use mozart::allocation::ExpertLayout;
use mozart::config::{ExperimentConfig, MethodConfig, ModelConfig, ModelId};
use mozart::coordinator::layouts_for;
use mozart::pipeline::{build_step_plan, PlanCache, StepInputs, StepWorkload};
use mozart::sim::{SimScratch, Simulator};
use mozart::testkit::bench;
use mozart::trace::{Priors, TraceGen};
use mozart::util::rng::Rng;

fn main() {
    let model = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
    let gen = TraceGen::for_model(&model, 7);

    bench("trace: sample_layer 8192 tokens top-8/128", 20, || {
        let mut rng = Rng::new(3);
        gen.sample_layer(0, 8192, &mut rng)
    });

    let mut rng = Rng::new(4);
    let tr = gen.sample_layer(0, 8192, &mut rng);
    bench("priors: V + 128x128 co-activation", 20, || {
        Priors::from_trace(&tr)
    });

    let priors = Priors::from_trace(&tr);
    bench("clustering: Algorithm 1, 128 experts -> 16", 20, || {
        mozart::clustering::cluster_experts(&priors, 16)
    });

    let clustering = mozart::clustering::cluster_experts(&priors, 16);
    let workloads = clustering.cluster_workloads(&priors);
    bench("allocation: exact B&B, 16 clusters -> 4 groups", 20, || {
        mozart::allocation::allocate(&workloads, 4)
    });

    let cfg = ExperimentConfig::paper_default(model.clone(), MethodConfig::mozart_c());
    let layouts = layouts_for(&cfg, &gen);
    let mut rng = Rng::new(5);
    let workload = StepWorkload::sample(&cfg, &gen, &layouts, true, &mut rng);
    bench("workload: full-step sampling (48 layers x 4 mb)", 5, || {
        let mut r = Rng::new(6);
        StepWorkload::sample(&cfg, &gen, &layouts, true, &mut r)
    });

    // topology-cache regression guard: a full one-shot build re-derives the
    // topology every pass (the pre-cache behavior); the cached retime pass
    // re-emits only durations/bytes over the reusable arena. The plans are
    // identical (asserted in plan_builder's tests); the gap is the cache win.
    let full = bench("plan: full rebuild (topology + emission each pass)", 10, || {
        build_step_plan(&StepInputs { cfg: &cfg, layouts: &layouts, workload: &workload })
            .n_tasks()
    });
    let mut plan_cache = PlanCache::new(&cfg, &layouts);
    plan_cache.rebuild(&workload);
    let retime = bench("plan: cached retime (reused arena)", 10, || {
        plan_cache.rebuild(&workload).n_tasks()
    });
    println!(
        "  (topology cache: {:.2}x faster than full rebuild)",
        full.mean_s / retime.mean_s
    );

    let plan = build_step_plan(&StepInputs { cfg: &cfg, layouts: &layouts, workload: &workload });
    println!("  (plan has {} tasks)", plan.n_tasks());
    bench("sim: discrete-event engine (throwaway scratch)", 10, || {
        Simulator::run(&plan)
    });
    let mut scratch = SimScratch::new();
    bench("sim: discrete-event engine (reused scratch)", 10, || {
        Simulator::run_with(&plan, &mut scratch).makespan
    });

    bench("a2a: C_T evaluation, 8192 tokens", 20, || {
        let layout = ExpertLayout::contiguous(128, 16, 4);
        mozart::comm::A2aStats::evaluate(&tr, &layout, true)
    });
}
