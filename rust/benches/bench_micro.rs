//! Micro-benches over the L3 hot paths: trace sampling, prior computation,
//! clustering, allocation, plan building, and the discrete-event engine.
//! These are the targets of the EXPERIMENTS.md §Perf iteration log.
use mozart::allocation::ExpertLayout;
use mozart::config::{ExperimentConfig, MethodConfig, ModelConfig, ModelId};
use mozart::coordinator::layouts_for;
use mozart::pipeline::{build_step_plan, StepInputs, StepWorkload};
use mozart::sim::Simulator;
use mozart::testkit::bench;
use mozart::trace::{Priors, TraceGen};
use mozart::util::rng::Rng;

fn main() {
    let model = ModelConfig::preset(ModelId::Qwen3_30B_A3B);
    let gen = TraceGen::for_model(&model, 7);

    bench("trace: sample_layer 8192 tokens top-8/128", 20, || {
        let mut rng = Rng::new(3);
        gen.sample_layer(0, 8192, &mut rng)
    });

    let mut rng = Rng::new(4);
    let tr = gen.sample_layer(0, 8192, &mut rng);
    bench("priors: V + 128x128 co-activation", 20, || {
        Priors::from_trace(&tr)
    });

    let priors = Priors::from_trace(&tr);
    bench("clustering: Algorithm 1, 128 experts -> 16", 20, || {
        mozart::clustering::cluster_experts(&priors, 16)
    });

    let clustering = mozart::clustering::cluster_experts(&priors, 16);
    let workloads = clustering.cluster_workloads(&priors);
    bench("allocation: exact B&B, 16 clusters -> 4 groups", 20, || {
        mozart::allocation::allocate(&workloads, 4)
    });

    let cfg = ExperimentConfig::paper_default(model.clone(), MethodConfig::mozart_c());
    let layouts = layouts_for(&cfg, &gen);
    let mut rng = Rng::new(5);
    let workload = StepWorkload::sample(&cfg, &gen, &layouts, true, &mut rng);
    bench("workload: full-step sampling (48 layers x 4 mb)", 5, || {
        let mut r = Rng::new(6);
        StepWorkload::sample(&cfg, &gen, &layouts, true, &mut r)
    });

    bench("plan: build step DAG (~60k tasks)", 10, || {
        build_step_plan(&StepInputs { cfg: &cfg, layouts: &layouts, workload: &workload })
    });

    let plan = build_step_plan(&StepInputs { cfg: &cfg, layouts: &layouts, workload: &workload });
    println!("  (plan has {} tasks)", plan.n_tasks());
    bench("sim: discrete-event engine over the step DAG", 10, || {
        Simulator::run(&plan)
    });

    bench("a2a: C_T evaluation, 8192 tokens", 20, || {
        let layout = ExpertLayout::contiguous(128, 16, 4);
        mozart::comm::A2aStats::evaluate(&tr, &layout, true)
    });
}
