//! Bench for appendix Figures 7/8/9: the full normalized-latency grid at
//! sequence lengths 128 / 256 / 512.
use mozart::report::{appendix_fig, ReportOpts};
use mozart::testkit::bench;

fn main() {
    let opts = ReportOpts { iters: 1, seed: 7 };
    for seq in [128usize, 256, 512] {
        let mut rendered = String::new();
        bench(&format!("fig{}: full grid seq {seq}", match seq { 128 => 7, 256 => 8, _ => 9 }), 1, || {
            rendered = appendix_fig(seq, opts);
        });
        println!("\n{rendered}");
    }
}
