//! Bench for paper Table 2: the analytic 28nm area/power model.
use mozart::report::table2;
use mozart::testkit::bench;

fn main() {
    let mut rendered = String::new();
    bench("table2: analytic area/power model", 50, || {
        rendered = table2();
    });
    println!("\n{rendered}");
}
