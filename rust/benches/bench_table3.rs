//! Bench for paper Table 3 / Figure 6(a): end-to-end latency of all four
//! methods on all three models (seq 256, HBM2), printing the same rows the
//! paper reports plus harness timings for the simulation itself.
use mozart::report::{table3, ReportOpts};
use mozart::testkit::bench;

fn main() {
    let opts = ReportOpts { iters: 2, seed: 7 };
    let mut rendered = String::new();
    bench("table3: 3 models x 4 methods (2 sim iters)", 3, || {
        rendered = table3(opts).0;
    });
    println!("\n{rendered}");
}
