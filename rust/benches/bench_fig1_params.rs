//! Bench for paper Figure 1: parameter distribution across module types.
use mozart::report::fig1;
use mozart::testkit::bench;

fn main() {
    let mut rendered = String::new();
    bench("fig1: parameter distribution", 50, || {
        rendered = fig1();
    });
    println!("\n{rendered}");
}
