//! Bench for paper Table 4: C_T vs normalized latency across methods.
use mozart::report::{table4, ReportOpts};
use mozart::testkit::bench;

fn main() {
    let opts = ReportOpts { iters: 2, seed: 7 };
    let mut rendered = String::new();
    bench("table4: C_T vs normalized latency", 3, || {
        rendered = table4(opts);
    });
    println!("\n{rendered}");
}
