//! Bench for appendix Figures 10-13: attention-vs-FFN roofline study over
//! OLMo-2 scales.
use mozart::report::fig10_13;
use mozart::testkit::bench;

fn main() {
    let mut rendered = String::new();
    bench("fig10-13: OLMo-2 roofline, 4 scales x 3 seqs", 50, || {
        rendered = fig10_13();
    });
    println!("\n{rendered}");
}
