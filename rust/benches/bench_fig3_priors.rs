//! Bench for paper Figure 3: activation-frequency and co-activation priors
//! (the profiling pass of §3.2).
use mozart::report::{fig3, ReportOpts};
use mozart::testkit::bench;

fn main() {
    let opts = ReportOpts { iters: 1, seed: 7 };
    let mut rendered = String::new();
    bench("fig3: 16k-token profiling + priors", 5, || {
        rendered = fig3(opts);
    });
    println!("\n{rendered}");
}
