//! Runtime benches: PJRT artifact load/compile latency and real train-step
//! throughput through the three-layer stack (requires `make artifacts`).
use mozart::testkit::bench;
use mozart::train::{run, ArtifactMeta, TrainConfig};

fn main() {
    if ArtifactMeta::load("artifacts").is_err() {
        eprintln!("skipping runtime bench: artifacts/ missing (run `make artifacts`)");
        return;
    }
    bench("runtime: load+compile tiny_moe_step HLO", 2, || {
        let rt = mozart::runtime::Runtime::cpu().unwrap();
        rt.load_hlo_text("artifacts/tiny_moe_step.hlo.txt").unwrap()
    });
    let mut summary = None;
    bench("runtime: 5 real train steps (B4 x T64)", 2, || {
        summary = Some(
            run(&TrainConfig {
                artifacts_dir: "artifacts".into(),
                steps: 5,
                log_every: 5,
                seed: 7,
            })
            .unwrap(),
        );
    });
    if let Some(s) = summary {
        println!("  throughput: {:.2} steps/s", s.steps_per_sec);
    }
}
