//! Algorithm-hardware co-design pipeline on a REAL model: train the tiny
//! MoE through the PJRT runtime, capture its actual routing statistics
//! (paper §3.2), feed them to the clustering/allocation algorithms, and
//! quantify the benefit on the simulated chiplet platform.
//!
//! This is the full Figure-2 loop of the paper running end to end: the
//! routing prior comes from real training instead of the synthetic
//! generator.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example codesign_pipeline -- [steps]

use mozart::allocation::{allocate, ExpertLayout};
use mozart::clustering::Clustering;
use mozart::train::{run, TrainConfig};

fn main() -> anyhow::Result<()> {
    let steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    // 1. real profiling run (the paper profiles the tuning set once)
    println!("== 1. profiling: {steps} real training steps through PJRT ==");
    let summary = run(&TrainConfig {
        artifacts_dir: "artifacts".to_string(),
        steps,
        log_every: (steps / 5).max(1),
        seed: 7,
    })?;
    println!(
        "loss {:.3} -> {:.3}, {:.2} steps/s",
        summary.initial_loss(),
        summary.final_loss(),
        summary.steps_per_sec
    );

    // 2. per-layer workload vectors (Eq. 3) from the real router
    let v = summary.workload_vectors();
    let n_experts = summary.meta_n_experts;
    println!("\n== 2. real routing prior (Eq. 3) ==");
    for (l, layer) in v.iter().enumerate() {
        let max = layer.iter().cloned().fold(0.0f64, f64::max);
        let cv = mozart::util::stats::cv(layer);
        println!("layer {l}: hottest expert {:.3} (uniform {:.3}), cv {:.3}", max, 1.0 / n_experts as f64, cv);
    }

    // 3. allocation (Eq. 5) on the real workloads: balance 16 single-expert
    // clusters over 4 chiplets for the tiny platform (4 experts/chiplet)
    println!("\n== 3. Eq. 5 allocation on real workloads (layer 0) ==");
    let n_chiplets = 4;
    let contiguous = Clustering::contiguous(n_experts, n_chiplets);
    let wl_cont = {
        // workload per contiguous cluster
        contiguous
            .clusters
            .iter()
            .map(|c| c.iter().map(|&e| v[0][e]).sum::<f64>())
            .collect::<Vec<_>>()
    };
    let balanced = allocate(&v[0], n_chiplets); // 16 clusters of one expert
    let wl_bal = balanced.group_workloads(&v[0]);
    println!(
        "contiguous chiplet workloads: {:?}",
        wl_cont.iter().map(|w| format!("{w:.3}")).collect::<Vec<_>>()
    );
    println!(
        "balanced   chiplet workloads: {:?}",
        wl_bal.iter().map(|w| format!("{w:.3}")).collect::<Vec<_>>()
    );
    println!(
        "imbalance (max/mean): contiguous {:.3} -> balanced {:.3}",
        mozart::util::stats::imbalance(&wl_cont),
        mozart::util::stats::imbalance(&wl_bal)
    );

    // 4. what the balanced layout buys on the simulated platform: the
    // straggler chiplet sets the expert-compute finish time
    println!("\n== 4. projected effect on the chiplet platform ==");
    let _ = ExpertLayout::contiguous(n_experts, n_chiplets, 2);
    let t_cont = mozart::util::stats::max(&wl_cont);
    let t_bal = mozart::util::stats::max(&wl_bal);
    println!(
        "expert-compute straggler share: {:.3} -> {:.3} ({:.1}% faster MoE phase)",
        t_cont,
        t_bal,
        (1.0 - t_bal / t_cont) * 100.0
    );
    println!("\ndone — the same prior drives `mozart report table3/table4` at paper scale");
    Ok(())
}
