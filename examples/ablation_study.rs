//! Ablation study over the design choices DESIGN.md calls out:
//! - streaming granularity (per-expert chunks vs whole-cluster loads)
//! - switch in-network aggregation factor
//! - group DRAM concurrency
//! - a2a/stream link sharing (occupancy)
//!
//! Each row re-simulates Mozart-C on Qwen3 (seq 256, HBM2) with one knob
//! moved, quantifying its contribution — the evidence behind the paper's
//! Q2 answer.
//!
//! Run: cargo run --release --example ablation_study

use mozart::config::{DramKind, Method, ModelId};
use mozart::coordinator::sweep::{cell_config, Cell};

fn run_with(
    label: &str,
    base_latency: Option<f64>,
    tweak: impl Fn(&mut mozart::config::ExperimentConfig),
) -> f64 {
    let cell = Cell {
        model: ModelId::Qwen3_30B_A3B,
        method: Method::MozartC,
        seq_len: 256,
        dram: DramKind::Hbm2,
    };
    let mut cfg = cell_config(cell, 2, 7);
    tweak(&mut cfg);
    let r = mozart::coordinator::run_experiment(&cfg);
    match base_latency {
        None => println!("{label:<46} {:.3} s/step (reference)", r.latency),
        Some(b) => println!(
            "{label:<46} {:.3} s/step ({:+.1}%)",
            r.latency,
            (r.latency / b - 1.0) * 100.0
        ),
    }
    r.latency
}

fn main() {
    println!("ablation: Mozart-C, Qwen3-30B-A3B, seq 256, HBM2\n");
    let base = run_with("calibrated configuration", None, |_| {});

    run_with("no switch in-network aggregation (agg=1)", Some(base), |c| {
        c.hw.knobs.switch_agg_factor = 1.0;
    });
    run_with("stronger aggregation (agg=4)", Some(base), |c| {
        c.hw.knobs.switch_agg_factor = 4.0;
    });
    run_with("single-stream group DRAM (concurrency=1)", Some(base), |c| {
        c.hw.knobs.group_concurrency = 1;
    });
    run_with("fully parallel group DRAM (concurrency=4)", Some(base), |c| {
        c.hw.knobs.group_concurrency = 4;
    });
    run_with("a2a monopolizes chiplet links (occ=1.0)", Some(base), |c| {
        c.hw.knobs.a2a_link_occupancy = 1.0;
    });
    run_with("a2a on dedicated links (occ=0.0)", Some(base), |c| {
        c.hw.knobs.a2a_link_occupancy = 0.0;
    });
    run_with("2x chunk overhead (coarser streaming)", Some(base), |c| {
        c.hw.knobs.chunk_overhead_us *= 2.0;
    });
    run_with("heavier optimizer traffic (opt=4x)", Some(base), |c| {
        c.hw.knobs.opt_traffic_factor = 4.0;
    });
    run_with("micro-batch 16 (coarser token streaming)", Some(base), |c| {
        c.micro_batch = 16;
    });
    run_with("micro-batch 4 (finer token streaming)", Some(base), |c| {
        c.micro_batch = 4;
    });
}
