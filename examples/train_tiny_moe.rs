//! End-to-end driver (session requirement): train a real MoE transformer
//! for a few hundred steps through the full three-layer stack — Pallas
//! kernels (L1) lowered inside the JAX model (L2) into an HLO artifact the
//! rust coordinator (L3) executes via PJRT — on a synthetic bigram corpus,
//! logging the loss curve and capturing the real routing prior.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example train_tiny_moe -- [steps]

use mozart::train::{run, TrainConfig};

fn main() -> anyhow::Result<()> {
    let steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let cfg = TrainConfig {
        artifacts_dir: "artifacts".to_string(),
        steps,
        log_every: (steps / 20).max(1),
        seed: 7,
    };
    let summary = run(&cfg)?;
    println!("{}", summary.render());

    // the real routing prior captured from training (paper §3.2 Eq. 3)
    let v = summary.workload_vectors();
    println!("real per-layer expert workload vectors (Eq. 3), layer 0:");
    for (e, w) in v[0].iter().enumerate() {
        println!("  expert {e:>2}: {:.4} {}", w, "#".repeat((w * 400.0) as usize));
    }
    let max = v[0].iter().cloned().fold(0.0f64, f64::max);
    let min = v[0].iter().cloned().fold(1.0f64, f64::min);
    println!(
        "specialization emerges even in a tiny model: max/min workload = {:.2}x",
        max / min.max(1e-9)
    );
    Ok(())
}
