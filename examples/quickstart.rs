//! Quickstart: the full Mozart algorithm pipeline on one model, in six
//! steps — profile the routing prior, cluster the experts (Algorithm 1),
//! allocate clusters to chiplet groups (Eq. 5), measure the all-to-all
//! complexity C_T, and simulate Baseline vs Mozart-C end-to-end.
//!
//! Run: cargo run --release --example quickstart

use mozart::allocation::ExpertLayout;
use mozart::comm::A2aStats;
use mozart::config::{DramKind, ExperimentConfig, Method, ModelConfig, ModelId};
use mozart::coordinator::sweep::{cell_config, Cell};
use mozart::trace::{Priors, TraceGen};
use mozart::util::rng::Rng;

fn main() {
    let model = ModelConfig::preset(ModelId::OlmoE_1B_7B);
    println!(
        "model: {} — {} experts, top-{}, {} MoE layers\n",
        model.id.name(),
        model.n_experts,
        model.top_k,
        model.n_moe_layers()
    );

    // 1. profile the routing prior (paper §3.2: prefill an instruction set)
    let gen = TraceGen::for_model(&model, 7);
    let mut rng = Rng::new(8);
    let trace = gen.sample_layer(0, 8_192, &mut rng);
    let priors = Priors::from_trace(&trace);
    let hottest = priors.hottest_pair();
    println!("1. profiled 8192 tokens: hottest co-activated pair = {hottest:?}");

    // 2. Algorithm 1 clustering
    let clustering = mozart::clustering::cluster_experts(&priors, 16);
    println!(
        "2. clustered {} experts into 16 clusters: intra-collab {:.4} (contiguous: {:.4})",
        model.n_experts,
        clustering.intra_collab(&priors),
        mozart::clustering::Clustering::contiguous(model.n_experts, 16).intra_collab(&priors)
    );

    // 3. Eq. 5 allocation
    let workloads = clustering.cluster_workloads(&priors);
    let allocation = mozart::allocation::allocate(&workloads, 4);
    println!(
        "3. allocated clusters to 4 groups: per-group workload {:?}",
        allocation
            .group_workloads(&workloads)
            .iter()
            .map(|w| format!("{w:.4}"))
            .collect::<Vec<_>>()
    );

    // 4. C_T under both layouts (paper §3.3)
    let mozart_layout = ExpertLayout::new(clustering, allocation, 4);
    let contiguous = ExpertLayout::contiguous(model.n_experts, 16, 4);
    let mut rng2 = Rng::new(9);
    let fresh = gen.sample_layer(0, 8_192, &mut rng2);
    let ct_cont = A2aStats::evaluate(&fresh, &contiguous, true).c_t;
    let ct_mozart = A2aStats::evaluate(&fresh, &mozart_layout, true).c_t;
    println!(
        "4. all-to-all complexity C_T: k={} -> contiguous {:.2} -> clustered {:.2}",
        model.top_k, ct_cont, ct_mozart
    );

    // 5+6. end-to-end simulation, Baseline vs Mozart-C
    for method in [Method::Baseline, Method::MozartC] {
        let cell = Cell {
            model: ModelId::OlmoE_1B_7B,
            method,
            seq_len: 256,
            dram: DramKind::Hbm2,
        };
        let cfg: ExperimentConfig = cell_config(cell, 2, 7);
        let r = mozart::coordinator::run_experiment(&cfg);
        println!(
            "5. simulate {:<9}: {:.3} s/step   C_T {:.2}   energy {:.0} J/step",
            method.name(),
            r.latency,
            r.c_t,
            r.energy.total_j()
        );
    }
    println!("\ndone — see `mozart report all` for every paper table/figure");
}
