//! Debug/utility example: load, compile, and optionally execute one HLO-text
//! artifact. Usage:
//!   cargo run --release --example load_artifact -- <path> [--run-init]

use anyhow::Result;
use mozart::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let path = args.first().expect("usage: load_artifact <path> [--run-init]");
    let rt = Runtime::cpu()?;
    eprintln!("parsing + compiling {path} ...");
    let exe = rt.load_hlo_text(path)?;
    eprintln!("compiled OK: {}", exe.name());
    if args.iter().any(|a| a == "--run-init") {
        eprintln!("executing with no args ...");
        let outs = exe.run(&[])?;
        eprintln!("executed OK: {} outputs", outs.len());
    }
    if args.iter().any(|a| a == "--run-step") {
        // init -> step smoke with host literals (no device buffers)
        let init = rt.load_hlo_text("artifacts/tiny_moe_init.hlo.txt")?;
        let state = init.run(&[])?;
        eprintln!("init gave {} state arrays", state.len());
        let meta = mozart::train::ArtifactMeta::load("artifacts")?;
        let mut corpus = mozart::train::data::Corpus::new(meta.vocab, 1);
        let (tok, tgt) = corpus.batch(meta.batch, meta.seq);
        let mut lits = state;
        lits.push(
            xla::Literal::vec1(&tok).reshape(&[meta.batch as i64, meta.seq as i64])?,
        );
        lits.push(
            xla::Literal::vec1(&tgt).reshape(&[meta.batch as i64, meta.seq as i64])?,
        );
        eprintln!("executing step with {} literal args ...", lits.len());
        let outs = exe.run(&lits)?;
        eprintln!("executed OK: {} outputs", outs.len());
        let loss = outs[outs.len() - 2].get_first_element::<f32>()?;
        eprintln!("loss = {loss}");
    }
    if args.iter().any(|a| a == "--run-step-b") {
        // same but through device buffers (the trainer's hot path)
        let init = rt.load_hlo_text("artifacts/tiny_moe_init.hlo.txt")?;
        let state = init.run(&[])?;
        eprintln!("init gave {} state arrays", state.len());
        let meta = mozart::train::ArtifactMeta::load("artifacts")?;
        let mut corpus = mozart::train::data::Corpus::new(meta.vocab, 1);
        let mut params: Vec<xla::PjRtBuffer> = state
            .iter()
            .map(|l| rt.to_device(l))
            .collect::<Result<_>>()?;
        for s in 0..3 {
            let (tok, tgt) = corpus.batch(meta.batch, meta.seq);
            let tok_lit =
                xla::Literal::vec1(&tok).reshape(&[meta.batch as i64, meta.seq as i64])?;
            let tgt_lit =
                xla::Literal::vec1(&tgt).reshape(&[meta.batch as i64, meta.seq as i64])?;
            let mut bufs = params;
            bufs.push(rt.to_device(&tok_lit)?);
            bufs.push(rt.to_device(&tgt_lit)?);
            eprintln!("step {s}: executing with {} buffers ...", bufs.len());
            let mut outs = exe.run_b(&bufs)?;
            eprintln!("step {s}: got {} outputs", outs.len());
            let counts = outs.pop().unwrap();
            let loss = outs.pop().unwrap();
            params = outs;
            let l = loss.to_literal_sync()?.get_first_element::<f32>()?;
            let _ = counts.to_literal_sync()?;
            eprintln!("step {s}: loss = {l}");
        }
    }
    Ok(())
}
