//! Design-space exploration: sweep hardware variants of the wafer-scale
//! platform around the paper's Table 2 point, compute the Pareto frontier
//! over (iteration latency, energy per step, die area), and report where
//! the paper's configuration lands — the algorithm-hardware co-design loop
//! the paper motivates, driven programmatically.
//!
//! Like every walkthrough in this directory, this is reference code outside
//! the cargo package (the equivalent CLI run is
//! `cargo run --release -p mozart -- explore --axes tiles=36:64:100,nop_bw,dram
//! --budget 12`); copy it into `rust/examples/` to build it as a cargo
//! example target.

use mozart::config::{DramKind, Method, ModelId, SchedPolicy};
use mozart::coordinator::cache::EvalOptions;
use mozart::coordinator::explore::{explore, parse_axes, ExploreConfig};

fn main() {
    // 1. declare the axes: tile count (compute), NoP link bandwidth
    //    (interconnect), and DRAM technology (memory) — with explicit
    //    values for the tiles axis to show the `axis=v1:v2` form.
    let axes = parse_axes("tiles=36:64:100,nop_bw,dram").expect("axes parse");
    let cfg = ExploreConfig {
        axes,
        budget: 12, // even-stride 12-of-24 subsample of the 3*4*2 grid
        models: vec![ModelId::OlmoE_1B_7B],
        methods: vec![Method::MozartC],
        seq_len: 128,
        dram: DramKind::Hbm2,
        iters: 2,
        seed: 7,
        threads: 0, // one worker per core
        // the paper's schedule; `SchedPolicy::ALL.to_vec()` would add the
        // per-platform schedule frontier (--scheds all) to the report
        scheds: vec![SchedPolicy::Streaming],
        eval: EvalOptions::default(), // cell memoization + delta re-timing on
    };

    // 2. run every (variant x model x method) cell through the same
    //    work-stealing pool as the paper sweeps
    let outcome = explore(&cfg);

    // 3. the rendered report: axis summary, frontier table, ASCII scatter,
    //    and the Q3-style verdict on the paper's Table 2 point
    println!("{}", outcome.render_markdown());

    // 4. the machine-readable artifact is one call away
    let json = outcome.to_json().render_pretty();
    println!("artifact: {} bytes of EXPLORE_*.json, e.g.:", json.len());
    for line in json.lines().take(12) {
        println!("  {line}");
    }
    println!("  ...");

    // 5. programmatic access: the frontier members and the anchor verdict
    let f = &outcome.frontiers[0];
    println!(
        "\nfrontier: {} of {} points non-dominated; paper anchor {}",
        f.members.len(),
        f.points.len(),
        if f.paper_dominators.is_empty() {
            "is on the frontier".to_string()
        } else {
            format!("is dominated by {} variant(s)", f.paper_dominators.len())
        }
    );
}
