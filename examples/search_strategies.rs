//! Guided design-space search: drive the constrained NSGA-II strategy over
//! a hardware axis grid — with the Mozart ablation and the DAG scheduling
//! policy as searchable genes — and read the archive + convergence curve
//! programmatically: the co-design loop of `mozart explore --strategy
//! evolutionary --methods all --scheds all --max-area ...`, as library code.
//!
//! Like every walkthrough in this directory, this is reference code outside
//! the cargo package (the equivalent CLI run is
//! `cargo run --release -p mozart -- explore --strategy evolutionary
//! --methods all --scheds all --max-area 16000 --population 8
//! --generations 6`); copy it into `rust/examples/` to build it as a cargo
//! example target.

use mozart::config::{DramKind, Method, ModelId, SchedPolicy};
use mozart::coordinator::cache::EvalOptions;
use mozart::coordinator::explore::{parse_axes, ExploreConfig};
use mozart::coordinator::search::{
    search_with, Constraints, SearchConfig, SearchStrategy,
};

fn main() {
    // 1. the design space: tile count, NoP link bandwidth, and a
    //    calibration-knob sensitivity axis (is the verdict robust to the
    //    DRAM-efficiency fit?)
    let axes = parse_axes("tiles,nop_bw,knob=dram_eff:0.6:0.95").expect("axes parse");

    // 2. constrained NSGA-II with the method and sched genes: each candidate
    //    is one (hardware point, Mozart ablation, dispatch policy) triple,
    //    the objectives are the worst case across the configured models, and
    //    candidates whose worst-case die area exceeds the budget never reach
    //    the frontier — they are ranked behind every feasible candidate
    let cfg = SearchConfig {
        constraints: Constraints {
            max_area_mm2: Some(16_000.0),
            // no power cap, no retained-throughput floor
            ..Constraints::none()
        },
        method_gene: true, // --methods all: "which ablation on which platform"
        sched_gene: true,  // --scheds all: "which dispatch policy on which platform"
        ..SearchConfig::new(
            ExploreConfig {
                axes,
                budget: 0,
                models: vec![ModelId::OlmoE_1B_7B, ModelId::DeepSeekMoE_16B],
                methods: Method::ALL.to_vec(),
                seq_len: 128,
                dram: DramKind::Hbm2,
                iters: 2,
                seed: 7, // one seed: simulation AND strategy are reproducible
                threads: 0,
                scheds: SchedPolicy::ALL.to_vec(),
                eval: EvalOptions::default(),
            },
            SearchStrategy::Evolutionary {
                population: 8,
                generations: 6,
                crossover_rate: 0.9, // 0.0 = mutation-only offspring
                mutation_rate: 0.3,
                seed: 7,
            },
        )
    };

    // 3. run with live per-generation progress (feasible count, archive
    //    size, hypervolume proxy — a flattening curve means convergence)
    let outcome = search_with(&cfg, |s| println!("{}", s.render()));

    // 4. the rendered report: axes, constraints + feasibility, the joint
    //    frontier table, scatter ('x' marks infeasible points), verdict
    println!("\n{}", outcome.render_markdown());

    // 5. programmatic access: every frontier member is feasible by
    //    construction and names its method gene
    for &ci in &outcome.archive {
        let j = &outcome.joint[ci];
        assert!(outcome.is_feasible(ci));
        println!(
            "frontier candidate `{}`: worst-case {:.3} s, {:.0} J/step, {:.0} mm^2, {:.0} W",
            outcome.candidates[ci].label, j.latency_s, j.energy_j, j.area_mm2, j.power_w
        );
    }
    println!(
        "{} of {} candidates feasible; paper anchor {} the joint frontier",
        outcome.n_feasible(),
        outcome.candidates.len(),
        if outcome.archive.contains(&0) {
            "is ON"
        } else {
            "is off"
        }
    );

    // 6. the EXPLORE_*.json artifact (with `search.feasibility`) is one
    //    call away
    let json = outcome.to_json().render_pretty();
    println!("\nartifact: {} bytes of EXPLORE_*.json", json.len());
}
