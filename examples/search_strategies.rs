//! Guided design-space search: drive the evolutionary strategy over a
//! hardware axis grid, jointly across several MoE models, and read the
//! archive + convergence curve programmatically — the co-design loop of
//! `mozart explore --strategy evolutionary --models all`, as library code.
//!
//! Like every walkthrough in this directory, this is reference code outside
//! the cargo package (the equivalent CLI run is
//! `cargo run --release -p mozart -- explore --strategy evolutionary
//! --models all --population 8 --generations 6`); copy it into
//! `rust/examples/` to build it as a cargo example target.

use mozart::config::{DramKind, Method, ModelId};
use mozart::coordinator::explore::{parse_axes, ExploreConfig};
use mozart::coordinator::search::{search_with, SearchConfig, SearchStrategy};

fn main() {
    // 1. the design space: tile count, NoP link bandwidth, and a
    //    calibration-knob sensitivity axis (is the verdict robust to the
    //    DRAM-efficiency fit?)
    let axes = parse_axes("tiles,nop_bw,knob=dram_eff:0.6:0.95").expect("axes parse");

    // 2. joint search across two models: a candidate's objectives are the
    //    WORST CASE of latency / energy / area over all configured models,
    //    so the frontier answers "which hardware is good for every model"
    let cfg = SearchConfig {
        explore: ExploreConfig {
            axes,
            budget: 0,
            models: vec![ModelId::OlmoE_1B_7B, ModelId::DeepSeekMoE_16B],
            methods: vec![Method::MozartC],
            seq_len: 128,
            dram: DramKind::Hbm2,
            iters: 2,
            seed: 7, // one seed: simulation AND strategy are reproducible
            threads: 0,
        },
        strategy: SearchStrategy::Evolutionary {
            population: 8,
            generations: 6,
            mutation_rate: 0.3,
            seed: 7,
        },
    };

    // 3. run with live per-generation progress (archive size + hypervolume
    //    proxy — a flattening curve means the search has converged)
    let outcome = search_with(&cfg, |s| {
        println!(
            "gen {:>2}: {:>4} candidates evaluated, archive {:>3}, hypervolume {:.4}",
            s.generation, s.evaluations, s.archive_size, s.hypervolume
        );
    });

    // 4. the rendered report: axes, joint frontier table, scatter, verdict
    println!("\n{}", outcome.render_markdown());

    // 5. programmatic access: archive members and the anchor verdict
    for &ci in &outcome.archive {
        let j = &outcome.joint[ci];
        println!(
            "frontier candidate `{}`: worst-case {:.3} s, {:.0} J/step, {:.0} mm^2",
            outcome.candidates[ci].label, j.latency_s, j.energy_j, j.area_mm2
        );
    }
    println!(
        "paper anchor {} the joint frontier",
        if outcome.paper_dominators.is_empty() {
            "is ON"
        } else {
            "is dominated off"
        }
    );

    // 6. the EXPLORE_*.json artifact (with the `search` section) is one
    //    call away
    let json = outcome.to_json().render_pretty();
    println!("\nartifact: {} bytes of EXPLORE_*.json", json.len());
}
