//! Calibration harness: grid-search the simulator's free knobs against the
//! paper's anchors (baseline Qwen3 seq-256 HBM2 = 4.87 s; Table 4
//! normalized latencies for Mozart-A/B/C on all three models).
//!
//! Routing workloads are sampled once per (model, method) — they do not
//! depend on the knobs — so the search only re-plans and re-simulates.
//!
//! Run: `cargo run --release --example calibrate [-- --fine]`

use mozart::config::{DramKind, ExperimentConfig, HwConfig, Method, ModelConfig, ModelId};
use mozart::coordinator::layouts_for;
use mozart::pipeline::{build_step_plan, StepInputs, StepWorkload};
use mozart::sim::Simulator;
use mozart::trace::TraceGen;
use mozart::util::rng::Rng;

struct Prepared {
    cfg: ExperimentConfig,
    layouts: Vec<mozart::allocation::ExpertLayout>,
    workload: StepWorkload,
}

fn prepare(model: ModelId, method: Method, seed: u64) -> Prepared {
    let m = ModelConfig::preset(model);
    let mut cfg = ExperimentConfig::paper_default(m, method.config());
    cfg.hw = HwConfig::paper_for_model(model, DramKind::Hbm2);
    cfg.seed = seed;
    let gen = TraceGen::for_model(&cfg.model, cfg.seed);
    let layouts = layouts_for(&cfg, &gen);
    let mut rng = Rng::new(seed ^ 0x5EED).fork(0);
    let workload =
        StepWorkload::sample(&cfg, &gen, &layouts, cfg.method.efficient_a2a, &mut rng);
    Prepared {
        cfg,
        layouts,
        workload,
    }
}

fn latency(p: &Prepared, knobs: &mozart::config::CalibrationKnobs) -> f64 {
    let mut cfg = p.cfg.clone();
    cfg.hw.knobs = knobs.clone();
    let plan = build_step_plan(&StepInputs {
        cfg: &cfg,
        layouts: &p.layouts,
        workload: &p.workload,
    });
    Simulator::run(&plan).makespan
}

fn main() {
    let fine = std::env::args().any(|a| a == "--fine");
    // paper anchors: normalized latency A/B/C per model + qwen3 baseline abs
    let anchors: [(ModelId, [f64; 3]); 3] = [
        (ModelId::Qwen3_30B_A3B, [0.73, 0.59, 0.52]),
        (ModelId::OlmoE_1B_7B, [0.63, 0.48, 0.422]),
        (ModelId::DeepSeekMoE_16B, [0.67, 0.56, 0.46]),
    ];
    let methods = Method::ALL;

    eprintln!("preparing workloads (sampled once per model x method)...");
    let prepared: Vec<Vec<Prepared>> = anchors
        .iter()
        .map(|(model, _)| {
            methods
                .iter()
                .map(|&meth| prepare(*model, meth, 7))
                .collect()
        })
        .collect();

    let occs: &[f64] = if fine {
        &[0.2, 0.3, 0.35, 0.4, 0.45]
    } else {
        &[0.0, 0.1, 0.2, 0.35]
    };
    let aggs: &[f64] = if fine {
        &[1.3, 1.45, 1.6, 1.8, 2.0]
    } else {
        &[1.0, 1.3, 1.6, 2.4, 3.2]
    };
    let opts: &[f64] = if fine {
        &[0.75, 1.0, 1.25, 1.5]
    } else {
        &[0.25, 0.5, 1.0]
    };
    let effs: &[f64] = if fine {
        &[0.36, 0.38, 0.4, 0.42, 0.44]
    } else {
        &[0.40, 0.44, 0.5, 0.56]
    };
    let concs: &[usize] = if fine { &[3, 4, 5] } else { &[2, 4, 6] };

    let mut best_err = f64::INFINITY;
    let mut best = mozart::config::CalibrationKnobs::default();
    for &conc in concs {
        for &occ in occs {
            for &agg in aggs {
                for &opt in opts {
                    for &eff in effs {
                        let mut k = mozart::config::CalibrationKnobs::default();
                        k.group_concurrency = conc;
                        k.a2a_link_occupancy = occ;
                        k.switch_agg_factor = agg;
                        k.opt_traffic_factor = opt;
                        k.nop_eff = eff;
                        let mut err = 0.0;
                        for (mi, (_, norms)) in anchors.iter().enumerate() {
                            let base = latency(&prepared[mi][0], &k);
                            if mi == 0 {
                                err += ((base - 4.87) / 4.87).powi(2);
                            }
                            for (j, &paper_norm) in norms.iter().enumerate() {
                                let lat = latency(&prepared[mi][j + 1], &k);
                                err += (lat / base - paper_norm).powi(2);
                            }
                        }
                        if err < best_err {
                            best_err = err;
                            best = k.clone();
                            eprintln!(
                                "err={err:.4} conc={conc} occ={occ} agg={agg} opt={opt} nop_eff={eff}"
                            );
                        }
                    }
                }
            }
        }
    }

    println!("\nbest knobs: {best:?} (err {best_err:.4})");
    println!("\nfit with best knobs:");
    println!("model, method, norm_sim, norm_paper, abs_sim");
    for (mi, (model, norms)) in anchors.iter().enumerate() {
        let base = latency(&prepared[mi][0], &best);
        println!("{}, Baseline, 1.000, 1.000, {base:.3}", model.name());
        for (j, &paper_norm) in norms.iter().enumerate() {
            let lat = latency(&prepared[mi][j + 1], &best);
            println!(
                "{}, {}, {:.3}, {paper_norm:.3}, {lat:.3}",
                model.name(),
                methods[j + 1].name(),
                lat / base
            );
        }
    }
}
