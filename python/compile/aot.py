"""AOT lowering: JAX/Pallas model -> HLO *text* artifacts for the rust
runtime. Python runs once here and never on the request path.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the published xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts [--report-vmem]
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.moe_ffn import vmem_report


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (with return_tuple=True, so
    the rust side unpacks one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_init(cfg: model.TinyMoEConfig) -> str:
    def init():
        return tuple(model.init_state(cfg, seed=0))

    return to_hlo_text(jax.jit(init).lower())


def lower_step(cfg: model.TinyMoEConfig) -> str:
    state = model.init_state(cfg, seed=0)
    spec = [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in state]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), "int32")

    def step(*args):
        return model.train_step(cfg, *args)

    return to_hlo_text(jax.jit(step).lower(*spec, tok, tok))


def write_meta(cfg: model.TinyMoEConfig, path: str) -> None:
    with open(path, "w") as f:
        f.write("# artifact metadata (KvConfig format, read by rust/src/train)\n")
        f.write(f"n_params = {model.n_state_arrays(cfg)}\n")
        f.write(f"batch = {cfg.batch}\n")
        f.write(f"seq = {cfg.seq}\n")
        f.write(f"vocab = {cfg.vocab}\n")
        f.write(f"n_layers = {cfg.n_layers}\n")
        f.write(f"n_experts = {cfg.n_experts}\n")
        f.write(f"top_k = {cfg.top_k}\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--report-vmem", action="store_true",
                    help="print the L1 kernel's VMEM/MXU estimate and exit")
    args = ap.parse_args()

    cfg = model.TinyMoEConfig()
    if args.report_vmem:
        rep = vmem_report(cfg.n_experts, cfg.capacity, cfg.hidden,
                          cfg.expert_intermediate)
        for k, v in rep.items():
            print(f"{k}: {v}")
        return

    os.makedirs(args.out_dir, exist_ok=True)

    init_hlo = lower_init(cfg)
    with open(os.path.join(args.out_dir, "tiny_moe_init.hlo.txt"), "w") as f:
        f.write(init_hlo)
    print(f"wrote tiny_moe_init.hlo.txt ({len(init_hlo)} chars)")

    step_hlo = lower_step(cfg)
    with open(os.path.join(args.out_dir, "tiny_moe_step.hlo.txt"), "w") as f:
        f.write(step_hlo)
    print(f"wrote tiny_moe_step.hlo.txt ({len(step_hlo)} chars)")

    write_meta(cfg, os.path.join(args.out_dir, "tiny_moe_meta.kv"))
    print("wrote tiny_moe_meta.kv")


if __name__ == "__main__":
    main()
