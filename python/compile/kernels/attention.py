"""L1 Pallas kernel: causal self-attention for one (batch, head) slab.

One grid step computes softmax(q k^T / sqrt(d) + causal) v for a whole
[T, d] head. The tiny end-to-end model uses short sequences, so one block
holds the full head in VMEM; the BlockSpec still expresses the HBM->VMEM
schedule per (batch*head) grid step (the attention chiplet's SRAM residency
in the paper's architecture).

interpret=True for the same reason as moe_ffn: CPU PJRT cannot run Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # [T, d]
    k = k_ref[0]
    v = v_ref[0]
    t, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # causal mask
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    scores = jnp.where(col <= row, scores, -1e30)
    # numerically-stable softmax
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _attn_bwd_kernel(q_ref, k_ref, v_ref, dy_ref, dq_ref, dk_ref, dv_ref):
    """Backward of one head's causal attention (recomputes the probability
    matrix, flash-style)."""
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    dy = dy_ref[0]
    t, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    scores = jnp.where(col <= row, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    dv = jnp.dot(p.T, dy, preferred_element_type=jnp.float32)
    dp = jnp.dot(dy, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale
    dk = jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale
    dq_ref[0] = dq.astype(dq_ref.dtype)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _attention(q, k, v, interpret):
    return _attention_fwd_call(q, k, v, interpret)


def _attention_fwd_call(q, k, v, interpret):
    bh, t, d = q.shape
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _attn_kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _attention_fwd(q, k, v, interpret):
    return _attention_fwd_call(q, k, v, interpret), (q, k, v)


def _attention_bwd(interpret, res, dy):
    q, k, v = res
    bh, t, d = q.shape
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    dq, dk, dv = pl.pallas_call(
        _attn_bwd_kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), q.dtype),
            jax.ShapeDtypeStruct((bh, t, d), k.dtype),
            jax.ShapeDtypeStruct((bh, t, d), v.dtype),
        ],
        interpret=interpret,
    )(q, k, v, dy)
    return dq, dk, dv


_attention.defvjp(_attention_fwd, _attention_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def causal_attention(q, k, v, *, interpret=True):
    """Multi-head causal attention.

    Args:
      q, k, v: [BH, T, d] (batch*heads merged in the leading dim).
    Returns:
      o: [BH, T, d]
    """
    bh, t, d = q.shape
    assert k.shape == (bh, t, d) and v.shape == (bh, t, d)
    return _attention(q, k, v, interpret)
