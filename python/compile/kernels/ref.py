"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package must match its oracle to numerical tolerance
across shapes and dtypes (see python/tests/test_kernels.py, which sweeps
them with hypothesis).
"""

import jax
import jax.numpy as jnp


def moe_ffn_ref(x, w_gate, w_up, w_down):
    """Reference grouped expert FFN: y[e] = silu(x@wg) * (x@wu) @ wd."""
    g = jnp.einsum("ech,ehi->eci", x, w_gate)
    u = jnp.einsum("ech,ehi->eci", x, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("eci,eih->ech", h, w_down).astype(x.dtype)


def causal_attention_ref(q, k, v):
    """Reference causal attention over [BH, T, d]."""
    t = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("btd,bsd->bts", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v).astype(q.dtype)
