"""L1 Pallas kernel: grouped expert FFN (the MoE compute hot-spot).

The kernel computes, for every expert e in the grid, a gated FFN over that
expert's capacity-padded token slab:

    y[e] = (silu(x[e] @ w_gate[e]) * (x[e] @ w_up[e])) @ w_down[e]

Hardware adaptation (DESIGN.md #Hardware-Adaptation): the paper maps expert
FFNs onto systolic-array tiles fed from a 3D-stacked SRAM die; on TPU the
analogous structure is an MXU-targeted matmul whose operand slabs live in
VMEM. The grid dimension over experts expresses the paper's
expert-to-chiplet spatial partitioning: each grid step touches only one
expert's weights, which is exactly the per-chiplet weight residency the
Mozart layout exploits. BlockSpec streams one expert slab (x: C x H,
weights: H x I / I x H) HBM->VMEM per grid step, the schedule the paper
implements with DRAM->SRAM weight streaming.

Pallas MUST run with interpret=True here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
both pytest and the rust runtime can run. Real-TPU perf is *estimated* from
the VMEM footprint / MXU shape analysis in `vmem_report()`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One expert's gated FFN. Refs carry a leading singleton expert dim."""
    x = x_ref[0]  # [C, H]
    wg = wg_ref[0]  # [H, I]
    wu = wu_ref[0]  # [H, I]
    wd = wd_ref[0]  # [I, H]
    # MXU-friendly: two [C,H]x[H,I] matmuls, gate, then [C,I]x[I,H]
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u
    o_ref[0] = jnp.dot(h, wd, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _ffn_bwd_kernel(x_ref, wg_ref, wu_ref, wd_ref, dy_ref,
                    dx_ref, dwg_ref, dwu_ref, dwd_ref):
    """Backward of one expert's gated FFN (rematerializes g/u/h, mirroring
    the paper's activation-streaming backward: inputs are re-read, hidden
    activations recomputed on-chip)."""
    x = x_ref[0]
    wg = wg_ref[0]
    wu = wu_ref[0]
    wd = wd_ref[0]
    dy = dy_ref[0]
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    sg = jax.nn.sigmoid(g)
    silu_g = g * sg
    h = silu_g * u
    dh = jnp.dot(dy, wd.T, preferred_element_type=jnp.float32)
    dwd = jnp.dot(h.T, dy, preferred_element_type=jnp.float32)
    dsilu = sg * (1.0 + g * (1.0 - sg))
    dg = dh * u * dsilu
    du = dh * silu_g
    dx = (jnp.dot(dg, wg.T, preferred_element_type=jnp.float32)
          + jnp.dot(du, wu.T, preferred_element_type=jnp.float32))
    dwg = jnp.dot(x.T, dg, preferred_element_type=jnp.float32)
    dwu = jnp.dot(x.T, du, preferred_element_type=jnp.float32)
    dx_ref[0] = dx.astype(dx_ref.dtype)
    dwg_ref[0] = dwg.astype(dwg_ref.dtype)
    dwu_ref[0] = dwu.astype(dwu_ref.dtype)
    dwd_ref[0] = dwd.astype(dwd_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _moe_ffn(x, w_gate, w_up, w_down, interpret):
    return _moe_ffn_fwd_call(x, w_gate, w_up, w_down, interpret)


def _moe_ffn_fwd_call(x, w_gate, w_up, w_down, interpret):
    e, c, h = x.shape
    i = w_gate.shape[-1]
    return pl.pallas_call(
        _ffn_kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, h), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, h, i), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, h, i), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, i, h), lambda e_: (e_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, h), lambda e_: (e_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, h), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)


def _moe_ffn_fwd(x, w_gate, w_up, w_down, interpret):
    y = _moe_ffn_fwd_call(x, w_gate, w_up, w_down, interpret)
    return y, (x, w_gate, w_up, w_down)


def _moe_ffn_bwd(interpret, res, dy):
    x, w_gate, w_up, w_down = res
    e, c, h = x.shape
    i = w_gate.shape[-1]
    dx, dwg, dwu, dwd = pl.pallas_call(
        _ffn_bwd_kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, c, h), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, h, i), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, h, i), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, i, h), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, c, h), lambda e_: (e_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, h), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, h, i), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, h, i), lambda e_: (e_, 0, 0)),
            pl.BlockSpec((1, i, h), lambda e_: (e_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, c, h), x.dtype),
            jax.ShapeDtypeStruct((e, h, i), w_gate.dtype),
            jax.ShapeDtypeStruct((e, h, i), w_up.dtype),
            jax.ShapeDtypeStruct((e, i, h), w_down.dtype),
        ],
        interpret=interpret,
    )(x, w_gate, w_up, w_down, dy)
    return dx, dwg, dwu, dwd


_moe_ffn.defvjp(_moe_ffn_fwd, _moe_ffn_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_ffn(x, w_gate, w_up, w_down, *, interpret=True):
    """Grouped expert FFN.

    Args:
      x:      [E, C, H] capacity-padded per-expert token slabs.
      w_gate: [E, H, I]
      w_up:   [E, H, I]
      w_down: [E, I, H]
    Returns:
      y:      [E, C, H]
    """
    e, c, h = x.shape
    _, _, i = w_gate.shape
    assert w_gate.shape == (e, h, i), w_gate.shape
    assert w_up.shape == (e, h, i), w_up.shape
    assert w_down.shape == (e, i, h), w_down.shape
    return _moe_ffn(x, w_gate, w_up, w_down, interpret)


def vmem_report(e, c, h, i, bytes_per_el=2):
    """Static VMEM/MXU analysis for one grid step (the L1 perf estimate).

    Returns a dict with the per-step VMEM footprint in bytes and the MXU
    utilization estimate for a 128x128 systolic array (fraction of lanes
    filled by the three matmuls, fill/drain amortization included).
    """
    vmem = (c * h + 2 * h * i + i * h + c * h) * bytes_per_el  # x, wg+wu, wd, y
    mxu = 128

    def util(m, k, n):
        # lane fill on the two systolic dims x pipeline efficiency over K
        fill = min(m, mxu) / mxu * min(n, mxu) / mxu
        pipe = k / (k + 2 * mxu)
        return fill * pipe

    u1 = util(c, h, i)  # gate/up matmuls
    u2 = util(c, i, h)  # down matmul
    flops = 2 * c * h * i * 3
    # weight by FLOP share of each matmul
    avg = (2 * (2 * c * h * i) * u1 + (2 * c * i * h) * u2) / flops
    return {
        "vmem_bytes_per_step": vmem,
        "mxu_utilization_est": avg,
        "flops_per_step": flops,
        "fits_16mb_vmem": vmem < 16 * 1024 * 1024,
    }
