"""L2: the tiny MoE decoder transformer in JAX (build-time only).

Mirrors the rust `ModelConfig::TinyMoE` preset: vocab 512, hidden 128,
4 layers, 4 heads (head_dim 32), 16 routed experts, top-2 routing, expert
intermediate 256. Capacity-based dispatch (GShard-style) keeps the dispatch
dense and Pallas-friendly; dropped-token fraction is negligible at capacity
factor 2 and is reported by the router stats anyway.

Calls the L1 Pallas kernels (`kernels.moe_ffn`, `kernels.attention`) inside
the forward pass so they lower into the same HLO artifact the rust runtime
executes. Adam is the optimizer; the full training state (params + both
moments + step counter) is threaded through `train_step` so the rust side
can keep everything on device between steps.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.attention import causal_attention
from compile.kernels.moe_ffn import moe_ffn


@dataclass(frozen=True)
class TinyMoEConfig:
    vocab: int = 512
    hidden: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    n_experts: int = 16
    top_k: int = 2
    expert_intermediate: int = 256
    batch: int = 4
    seq: int = 64
    capacity_factor: float = 2.0
    lr: float = 3e-3
    adam_b1: float = 0.9
    adam_b2: float = 0.99
    adam_eps: float = 1e-8

    @property
    def capacity(self) -> int:
        tokens = self.batch * self.seq
        return int(self.capacity_factor * tokens * self.top_k / self.n_experts)


# parameter tree is a flat, ordered list of named arrays so the AOT artifact
# has a stable, documented calling convention for the rust runtime
PARAM_NAMES = [
    "embed",      # [V, H]
    "wq", "wk", "wv", "wo",   # [L, H, H] each
    "router",     # [L, H, E]
    "w_gate", "w_up",         # [L, E, H, I]
    "w_down",     # [L, E, I, H]
    "norm_attn", "norm_moe",  # [L, H]
    "norm_out",   # [H]
    "head",       # [H, V]
]


def init_params(cfg: TinyMoEConfig, seed: int = 0):
    """Deterministic parameter init; returns the ordered param list."""
    k = jax.random.split(jax.random.PRNGKey(seed), 16)
    h, v, l, e, i = cfg.hidden, cfg.vocab, cfg.n_layers, cfg.n_experts, cfg.expert_intermediate
    s = lambda *dims: (2.0 / sum(dims[-2:])) ** 0.5  # he-ish scale

    def rnd(key, *dims):
        return jax.random.normal(key, dims, jnp.float32) * s(*dims)

    return [
        rnd(k[0], v, h),
        rnd(k[1], l, h, h),
        rnd(k[2], l, h, h),
        rnd(k[3], l, h, h),
        rnd(k[4], l, h, h),
        rnd(k[5], l, h, e),
        rnd(k[6], l, e, h, i),
        rnd(k[7], l, e, h, i),
        rnd(k[8], l, e, i, h),
        jnp.ones((l, h), jnp.float32),
        jnp.ones((l, h), jnp.float32),
        jnp.ones((h,), jnp.float32),
        rnd(k[9], h, v),
    ]


def _top_k(x, k):
    """top-k via iterated argmax: lowers to plain HLO (the xla_extension
    0.5.1 text parser predates the TopK op's `largest` attribute)."""
    vals, idxs = [], []
    cur = x
    for _ in range(k):
        i = jnp.argmax(cur, axis=-1)  # [T]
        v = jnp.take_along_axis(cur, i[:, None], axis=-1)[:, 0]
        vals.append(v)
        idxs.append(i)
        cur = cur - jax.nn.one_hot(i, x.shape[-1], dtype=cur.dtype) * 1e30
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _rms_norm(x, w):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * w


def _moe_layer(cfg: TinyMoEConfig, x, router_w, w_gate, w_up, w_down):
    """Top-k capacity-dispatch MoE layer; returns (y, per-expert counts)."""
    t, h = x.shape
    e, c, k = cfg.n_experts, cfg.capacity, cfg.top_k

    gates = jax.nn.softmax(x @ router_w, axis=-1)  # [T, E]
    topv, topi = _top_k(gates, k)  # [T, k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(topi.reshape(-1), e, dtype=jnp.float32)  # [T*k, E]
    counts = jnp.sum(onehot, axis=0)  # [E] — the routing prior Eq. 3 feeds on
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.sum(pos * onehot, axis=-1)  # [T*k]
    keep = (pos_in_e < c).astype(jnp.float32)
    pos_onehot = jax.nn.one_hot(pos_in_e, c, dtype=jnp.float32)  # [T*k, C]
    disp = (
        onehot[:, :, None] * pos_onehot[:, None, :] * keep[:, None, None]
    ).reshape(t, k, e, c)

    x_e = jnp.einsum("tkec,th->ech", disp, x)
    y_e = moe_ffn(x_e, w_gate, w_up, w_down)  # L1 Pallas kernel
    y = jnp.einsum("tkec,ech,tk->th", disp, y_e, topv)
    return y, counts


def forward(cfg: TinyMoEConfig, params, tokens):
    """Forward pass. tokens: i32 [B, T] -> (logits [B, T, V], counts [L, E])."""
    (embed, wq, wk, wv, wo, router, w_gate, w_up, w_down,
     norm_attn, norm_moe, norm_out, head) = params
    b, t = tokens.shape
    h, nh, dh = cfg.hidden, cfg.n_heads, cfg.head_dim

    x = embed[tokens]  # [B, T, H]
    all_counts = []
    for l in range(cfg.n_layers):
        # attention (L1 Pallas kernel for the score/value path)
        xa = _rms_norm(x, norm_attn[l])
        q = (xa @ wq[l]).reshape(b, t, nh, dh).transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
        kk = (xa @ wk[l]).reshape(b, t, nh, dh).transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
        vv = (xa @ wv[l]).reshape(b, t, nh, dh).transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
        o = causal_attention(q, kk, vv)
        o = o.reshape(b, nh, t, dh).transpose(0, 2, 1, 3).reshape(b, t, h)
        x = x + o @ wo[l]

        # MoE FFN
        xm = _rms_norm(x, norm_moe[l]).reshape(b * t, h)
        y, counts = _moe_layer(cfg, xm, router[l], w_gate[l], w_up[l], w_down[l])
        x = x + y.reshape(b, t, h)
        all_counts.append(counts)

    logits = _rms_norm(x, norm_out) @ head
    return logits, jnp.stack(all_counts)  # [L, E]


def loss_fn(cfg: TinyMoEConfig, params, tokens, targets):
    logits, counts = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).squeeze(-1)
    return jnp.mean(nll), counts


def init_state(cfg: TinyMoEConfig, seed: int = 0):
    """Full Adam state: params + first/second moments + step counter."""
    params = init_params(cfg, seed)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jnp.zeros((), jnp.float32)
    return params + m + v + [step]


def n_state_arrays(cfg: TinyMoEConfig) -> int:
    return 3 * len(PARAM_NAMES) + 1


def train_step(cfg: TinyMoEConfig, *args):
    """One Adam step.

    args = (*state, tokens, targets) where state is the flat list from
    `init_state`. Returns (*new_state, loss, router_counts).
    """
    n = len(PARAM_NAMES)
    state, tokens, targets = list(args[:-2]), args[-2], args[-1]
    params, m, v, step = state[:n], state[n:2 * n], state[2 * n:3 * n], state[3 * n]

    (loss, counts), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, targets), has_aux=True
    )(params)

    step = step + 1.0
    b1, b2, eps, lr = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.lr
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1 ** step)
        vhat = vi / (1 - b2 ** step)
        new_params.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)

    return tuple(new_params + new_m + new_v + [step, loss, counts])
