"""L2 model tests: shapes, routing invariants, and learning signal."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model

jax.config.update("jax_platform_name", "cpu")

SMALL = model.TinyMoEConfig(
    vocab=64, hidden=32, n_layers=2, n_heads=2, head_dim=16,
    n_experts=8, top_k=2, expert_intermediate=64, batch=2, seq=16,
)


def test_init_shapes():
    params = model.init_params(SMALL)
    assert len(params) == len(model.PARAM_NAMES)
    by_name = dict(zip(model.PARAM_NAMES, params))
    assert by_name["embed"].shape == (64, 32)
    assert by_name["wq"].shape == (2, 32, 32)
    assert by_name["router"].shape == (2, 32, 8)
    assert by_name["w_gate"].shape == (2, 8, 32, 64)
    assert by_name["w_down"].shape == (2, 8, 64, 32)
    assert by_name["head"].shape == (32, 64)


def test_forward_shapes_and_counts():
    params = model.init_params(SMALL)
    tokens = jnp.zeros((SMALL.batch, SMALL.seq), jnp.int32)
    logits, counts = model.forward(SMALL, params, tokens)
    assert logits.shape == (SMALL.batch, SMALL.seq, SMALL.vocab)
    assert counts.shape == (SMALL.n_layers, SMALL.n_experts)
    # every token picks exactly top_k experts per layer
    tk = SMALL.batch * SMALL.seq * SMALL.top_k
    np.testing.assert_allclose(np.asarray(counts).sum(axis=-1), tk)


def test_initial_loss_near_uniform():
    params = model.init_params(SMALL)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, SMALL.vocab, (SMALL.batch, SMALL.seq)), jnp.int32)
    loss, _ = model.loss_fn(SMALL, params, tokens, tokens)
    assert abs(float(loss) - np.log(SMALL.vocab)) < 1.0


def test_train_step_reduces_loss_on_fixed_batch():
    state = model.init_state(SMALL, seed=0)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, SMALL.vocab, (SMALL.batch, SMALL.seq)), jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    step = jax.jit(lambda *a: model.train_step(SMALL, *a))
    first = None
    for _ in range(8):
        out = step(*state, tokens, targets)
        state = list(out[:-2])
        loss = float(out[-2])
        if first is None:
            first = loss
    assert loss < first, f"{loss} !< {first}"


def test_top_k_matches_lax():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    v_ours, i_ours = model._top_k(x, 4)
    v_lax, i_lax = jax.lax.top_k(x, 4)
    np.testing.assert_allclose(v_ours, v_lax, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i_ours), np.asarray(i_lax))


def test_capacity_drops_are_bounded():
    # with capacity factor 2 and near-uniform routing at init, drops are rare
    params = model.init_params(SMALL)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, SMALL.vocab, (SMALL.batch, SMALL.seq)), jnp.int32)
    _, counts = model.forward(SMALL, params, tokens)
    # no expert can receive more slots than exist
    assert np.asarray(counts).max() <= SMALL.batch * SMALL.seq * SMALL.top_k


def test_n_state_arrays_matches_init():
    assert len(model.init_state(SMALL)) == model.n_state_arrays(SMALL)
