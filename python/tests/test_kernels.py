"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; fixed-seed numpy provides the data.
These are the CORE correctness signal for the compute layer — the same
kernels lower into the AOT artifact the rust runtime executes.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import causal_attention
from compile.kernels.moe_ffn import moe_ffn, vmem_report
from compile.kernels.ref import causal_attention_ref, moe_ffn_ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# ---------------- moe_ffn ----------------

@settings(max_examples=20, deadline=None)
@given(
    e=st.sampled_from([1, 2, 4, 8]),
    c=st.sampled_from([1, 8, 32, 64]),
    h=st.sampled_from([8, 32, 128]),
    i=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_ffn_matches_ref(e, c, h, i, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, e, c, h)
    wg = rand(rng, e, h, i)
    wu = rand(rng, e, h, i)
    wd = rand(rng, e, i, h)
    got = moe_ffn(x, wg, wu, wd)
    want = moe_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_moe_ffn_dtypes(dtype):
    # the kernel accumulates in f32 regardless of input dtype, so compare
    # against the f32 ground truth with a tolerance set by the input dtype's
    # representational error (the fp16 ref itself rounds per-op and is the
    # *less* accurate of the two)
    rng = np.random.default_rng(0)
    x = rand(rng, 2, 16, 32, dtype=dtype)
    wg = rand(rng, 2, 32, 64, dtype=dtype)
    wu = rand(rng, 2, 32, 64, dtype=dtype)
    wd = rand(rng, 2, 64, 32, dtype=dtype)
    got = moe_ffn(x, wg, wu, wd)
    want32 = moe_ffn_ref(
        x.astype(np.float32), wg.astype(np.float32),
        wu.astype(np.float32), wd.astype(np.float32),
    )
    assert got.dtype == x.dtype
    scale = float(np.max(np.abs(want32)))
    tol = 1e-4 if dtype == np.float32 else 5e-3
    np.testing.assert_allclose(
        got.astype(np.float32), want32, rtol=2e-2, atol=tol * scale
    )


def test_moe_ffn_experts_are_independent():
    # zeroing one expert's input must not change another expert's output
    rng = np.random.default_rng(1)
    x = rand(rng, 4, 8, 16)
    wg = rand(rng, 4, 16, 32)
    wu = rand(rng, 4, 16, 32)
    wd = rand(rng, 4, 32, 16)
    base = moe_ffn(x, wg, wu, wd)
    x2 = x.at[0].set(0.0)
    out = moe_ffn(x2, wg, wu, wd)
    np.testing.assert_allclose(out[1:], base[1:], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out[0], base[0])


def test_moe_ffn_gradients_match_ref():
    # custom_vjp backward kernel vs autodiff of the oracle
    rng = np.random.default_rng(2)
    x = rand(rng, 2, 8, 16)
    wg = rand(rng, 2, 16, 32)
    wu = rand(rng, 2, 16, 32)
    wd = rand(rng, 2, 32, 16)

    def loss_pallas(*a):
        return jnp.sum(moe_ffn(*a) ** 2)

    def loss_ref(*a):
        return jnp.sum(moe_ffn_ref(*a) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


def test_vmem_report_structure():
    rep = vmem_report(16, 64, 128, 256)
    assert rep["fits_16mb_vmem"]
    assert 0.0 < rep["mxu_utilization_est"] <= 1.0
    assert rep["flops_per_step"] == 2 * 64 * 128 * 256 * 3


# ---------------- attention ----------------

@settings(max_examples=16, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 8]),
    t=st.sampled_from([1, 4, 16, 64]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bh, t, d, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, bh, t, d)
    k = rand(rng, bh, t, d)
    v = rand(rng, bh, t, d)
    got = causal_attention(q, k, v)
    want = causal_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_attention_is_causal():
    # changing future keys/values must not change earlier outputs
    rng = np.random.default_rng(3)
    q = rand(rng, 1, 8, 16)
    k = rand(rng, 1, 8, 16)
    v = rand(rng, 1, 8, 16)
    base = causal_attention(q, k, v)
    k2 = k.at[:, -1].add(10.0)
    v2 = v.at[:, -1].add(10.0)
    out = causal_attention(q, k2, v2)
    np.testing.assert_allclose(out[:, :-1], base[:, :-1], rtol=1e-5, atol=1e-5)


def test_attention_first_token_copies_v():
    # token 0 can only attend to itself -> output == v[0]
    rng = np.random.default_rng(4)
    q = rand(rng, 2, 6, 8)
    k = rand(rng, 2, 6, 8)
    v = rand(rng, 2, 6, 8)
    out = causal_attention(q, k, v)
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-5)


def test_attention_gradients_match_ref():
    rng = np.random.default_rng(5)
    q = rand(rng, 2, 8, 16)
    k = rand(rng, 2, 8, 16)
    v = rand(rng, 2, 8, 16)

    gp = jax.grad(lambda *a: jnp.sum(causal_attention(*a) ** 2), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(causal_attention_ref(*a) ** 2), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)
