"""AOT lowering tests: the HLO-text interchange contract with the rust
runtime (stable entry computation, tuple returns, metadata consistency)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

SMALL = model.TinyMoEConfig(
    vocab=64, hidden=32, n_layers=2, n_heads=2, head_dim=16,
    n_experts=8, top_k=2, expert_intermediate=64, batch=2, seq=16,
)


def test_init_lowering_is_hlo_text():
    text = aot.lower_init(SMALL)
    assert "HloModule" in text
    assert "ROOT" in text
    # no Mosaic custom-calls may appear (CPU PJRT cannot run them)
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_step_lowering_parameter_count():
    text = aot.lower_step(SMALL)
    n = model.n_state_arrays(SMALL) + 2  # state + tokens + targets
    # every parameter appears as parameter(i)
    for i in range(n):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    assert f"parameter({n})" not in text


def test_step_lowering_avoids_new_topk_attr():
    # regression: jax's TopK lowers with a `largest` attribute the
    # xla_extension 0.5.1 parser rejects; we use iterated argmax instead
    text = aot.lower_step(SMALL)
    assert "largest=" not in text


def test_meta_roundtrip(tmp_path):
    p = tmp_path / "tiny_moe_meta.kv"
    aot.write_meta(SMALL, str(p))
    content = p.read_text()
    meta = {}
    for line in content.splitlines():
        if "=" in line and not line.startswith("#"):
            k, v = line.split("=")
            meta[k.strip()] = int(v.strip())
    assert meta["n_params"] == model.n_state_arrays(SMALL)
    assert meta["batch"] == SMALL.batch
    assert meta["seq"] == SMALL.seq
    assert meta["vocab"] == SMALL.vocab
    assert meta["n_experts"] == SMALL.n_experts


def test_lowered_step_executes_in_jax():
    # sanity: the jitted step that gets lowered actually runs and returns
    # the documented output arity
    state = model.init_state(SMALL)
    tokens = jnp.zeros((SMALL.batch, SMALL.seq), jnp.int32)
    out = model.train_step(SMALL, *state, tokens, tokens)
    assert len(out) == model.n_state_arrays(SMALL) + 2
    loss = out[-2]
    counts = out[-1]
    assert loss.shape == ()
    assert counts.shape == (SMALL.n_layers, SMALL.n_experts)
    assert np.isfinite(float(loss))
